"""End-to-end training driver with the PBDS-sketched data pipeline.

Runs any ``--arch`` (full or ``--smoke`` reduced config) on the host mesh:
curation query -> cost-based sketch selection -> fragment-skipping loader ->
jitted train_step with grad accumulation -> checkpoint/resume -> straggler
monitoring.  On the CPU container this drives smoke-scale models; the same
code path lowers against the production mesh in dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import ARCHS, get_config
from repro.data import CurationSpec, SketchedDataPipeline, make_corpus_metadata
from repro.launch.mesh import make_host_mesh
from repro.models.config import ModelConfig
from repro.optim.adamw import OptConfig
from repro.runtime import StragglerMonitor
from repro.train.step import TrainSpec, init_train_state, make_train_step, microbatch_reshape


def make_batch_for(cfg: ModelConfig, raw, seq: int):
    """Adapt raw token batches to the arch's input signature."""
    tokens = jnp.asarray(raw["tokens"][:, :seq])
    batch = {"tokens": tokens}
    b = tokens.shape[0]
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.zeros((b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros((b, seq, cfg.frontend_dim), jnp.float32)
    return batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--no-smoke", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--quality-threshold", type=float, default=0.55)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    print(f"[train] arch={cfg.name} params={cfg.param_count():,}")

    # --- PBDS data curation (the paper's technique, online) ----------------
    meta = make_corpus_metadata(n_docs=20_000, seed=args.seed)
    cur = CurationSpec(having_value=args.quality_threshold)
    pipe = SketchedDataPipeline(
        meta, cur, args.batch, args.seq, cfg.vocab_size, seed=args.seed
    )
    ri = pipe.run_info
    print(
        f"[train] curation: strategy={ri.strategy} attr={ri.attr} "
        f"sketch_sel={ri.selectivity if ri.selectivity is not None else 1.0:.3f} "
        f"skipped={pipe.skipped_fraction:.1%} of corpus "
        f"(select={ri.t_select*1e3:.0f}ms capture={ri.t_capture*1e3:.0f}ms)"
    )

    # --- model / optimizer ---------------------------------------------------
    spec = TrainSpec(microbatch=args.n_micro, opt=OptConfig(total_steps=max(args.steps, 2)))
    state = init_train_state(jax.random.PRNGKey(args.seed), cfg, spec)
    step_fn = jax.jit(make_train_step(cfg, spec), donate_argnums=(0,))
    ckpt = CheckpointManager(args.ckpt, keep=3)

    start = 0
    if args.resume:
        try:
            state, extra = ckpt.restore(state)
            start = int(extra.get("step", 0))
            pipe.restore(extra.get("pipeline", pipe.state()))
            print(f"[train] resumed from step {start}")
        except FileNotFoundError:
            print("[train] no checkpoint found; fresh start")

    mon = StragglerMonitor()
    it = iter(pipe)
    losses = []
    for step in range(start, args.steps):
        t0 = time.perf_counter()
        raw = next(it)
        batch = microbatch_reshape(make_batch_for(cfg, raw, args.seq), args.n_micro)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = mon.observe(dt)
        losses.append(loss)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step={step} loss={loss:.4f} gnorm={float(metrics['grad_norm']):.3f} "
                  f"dt={dt*1e3:.0f}ms{' STRAGGLER' if slow else ''}")
        if (step + 1) % args.ckpt_every == 0 or step == args.steps - 1:
            ckpt.save(step + 1, state, extra={"step": step + 1, "pipeline": pipe.state()})
    ckpt.wait()
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"(improved={losses[-1] < losses[0]}) ckpts={ckpt.all_steps()}")


if __name__ == "__main__":
    main()
