"""Production mesh construction (a FUNCTION so importing never touches jax
device state — the dry-run sets XLA_FLAGS before any jax initialization)."""
from __future__ import annotations

import functools

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (smoke tests / examples): (n_devices, 1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


@functools.lru_cache(maxsize=None)
def make_serving_mesh(min_devices: int = 2):
    """1-D mesh over the local accelerators for SPMD sharded serving.

    The sharded engine's fused routed launch shard_maps its stacked
    (query, shard, row) arrays over the ``"shards"`` axis and psum-merges the
    per-shard partials.  Returns ``None`` on hosts with fewer than
    ``min_devices`` devices — there the same stacked launch runs as one
    single-device program (the vmapped fallback), so callers can treat the
    mesh as a pure placement hint.  Cached (the local device set is fixed for
    the process) so every engine shares ONE mesh object and the jitted
    shard_map programs keyed on it never recompile per engine.
    """
    devices = jax.local_devices()
    if len(devices) < min_devices:
        return None
    return jax.make_mesh((len(devices),), ("shards",))
