"""Production mesh construction (a FUNCTION so importing never touches jax
device state — the dry-run sets XLA_FLAGS before any jax initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (smoke tests / examples): (n_devices, 1)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
