"""HLO-text analysis: collective traffic and dot FLOPs with loop multipliers.

``compiled.cost_analysis()`` only covers the entry computation — everything
under a ``lax.scan`` (the period stack, grad accumulation, attention chunks)
lives in separate while-body computations and is invisible to it.  This
module walks the optimized HLO text instead:

  1. split into computations; build the call graph (fusion ``calls=``,
     ``body=``/``condition=`` of whiles, ``branch_computations``, ``to_apply``);
  2. recover while trip counts from the loop condition's s32 constant;
  3. propagate multiplicities through the graph (a collective inside the
     period scan inside the grad-accum scan counts n_periods * n_micro times);
  4. sum (a) result bytes of all-gather / all-reduce / reduce-scatter /
     all-to-all / collective-permute ops and (b) 2*M*N*K FLOPs of dot ops.

All sizes are per-device (post-SPMD shapes).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)(?:-start)?\("
)
_COLL_DONE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)-done\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HEAD_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\(|\.)")
_CALL_EDGE_RE = re.compile(
    r"(?:calls|body|to_apply)=%?([\w.\-]+)"
)
_COND_EDGE_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_DOT_RE = re.compile(r"=\s*([^=]*?)\s*dot\(")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"dot\(\s*%?([\w.\-]+)\s*,")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)")


def _shape_bytes(s: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(s: str) -> List[int]:
    m = _SHAPE_RE.search(s)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d.strip()]


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> list of its op lines."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s:
            continue
        # Computation headers end with '{' and contain '->'.
        if s.endswith("{") and "->" in s and ("(" in s):
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", s)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if s == "}":
            continue
        if cur is not None:
            comps[cur].append(s)
    return comps


def _fixpoint_multipliers(comps, edges, roots) -> Dict[str, float]:
    mult: Dict[str, float] = {n: 0.0 for n in comps}
    for r in roots:
        mult[r] = 1.0
    for _ in range(len(comps) + 2):
        new = {n: 0.0 for n in comps}
        for r in roots:
            new[r] = 1.0
        for caller, outs in edges.items():
            cm = mult.get(caller, 0.0)
            if cm <= 0:
                continue
            for callee, m in outs:
                if callee in new:
                    new[callee] += cm * m
        if all(abs(new[n] - mult[n]) < 1e-6 for n in comps):
            mult = new
            break
        mult = new
    return mult


_TRIP_CFG_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def analyze_hlo(hlo: str) -> Dict[str, object]:
    comps = _split_computations(hlo)
    edges: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    # Fallback trip detection: the loop-condition computation compares the
    # induction variable against an s32 constant (possibly via a fusion).
    cond_trip: Dict[str, int] = {}
    for name, lines in comps.items():
        consts = [int(m.group(1)) for l in lines for m in _CONST_S32_RE.finditer(l)]
        if consts and any("compare" in l or "wrapped_compare" in l for l in lines):
            cond_trip[name] = max(consts)
    while_trips: Dict[str, float] = {}
    for name, lines in comps.items():
        for l in lines:
            if " while(" in l:
                cm = _COND_EDGE_RE.search(l)
                bm = re.search(r"body=%?([\w.\-]+)", l)
                tm = _TRIP_CFG_RE.search(l)
                if tm:
                    trips = float(tm.group(1))
                elif cm and cm.group(1) in cond_trip:
                    trips = float(cond_trip[cm.group(1)])
                else:
                    trips = 1.0
                if bm:
                    edges[name].append((bm.group(1), trips))
                    while_trips[bm.group(1)] = trips
                if cm:
                    edges[name].append((cm.group(1), trips))
                continue
            for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", l):
                edges[name].append((m.group(1), 1.0))
            bm2 = _BRANCH_RE.search(l)
            if bm2:
                for b in bm2.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b:
                        edges[name].append((b, 1.0))

    called = {c for outs in edges.values() for c, _ in outs}
    roots = [n for n in comps if n not in called] or list(comps)[:1]
    mult = _fixpoint_multipliers(comps, edges, roots)

    coll_total = 0.0
    coll_kind: Dict[str, float] = defaultdict(float)
    flops_total = 0.0
    n_dots = 0
    for name, lines in comps.items():
        m_comp = mult.get(name, 0.0)
        if m_comp <= 0:
            continue
        # Symbol table for operand shapes (needed for dot contraction sizes).
        shapes: Dict[str, str] = {}
        for l in lines:
            dm = _DEF_RE.match(l)
            if dm:
                shapes[dm.group(1)] = dm.group(2)
        for l in lines:
            if _COLL_DONE_RE.search(l):
                continue
            cm = _COLL_RE.search(l)
            if cm and "=" in l:
                out_shape = l.split("=", 1)[1].split(cm.group(1))[0]
                b = _shape_bytes(out_shape)
                coll_total += b * m_comp
                coll_kind[cm.group(1)] += b * m_comp
                continue
            if " dot(" in l or l.startswith("dot("):
                dm = _DOT_RE.search(l)
                if not dm:
                    continue
                out_dims = _shape_dims(dm.group(1))
                lhs_m = _OPERANDS_RE.search(l)
                con_m = _CONTRACT_RE.search(l)
                if not (lhs_m and con_m):
                    continue
                lhs_shape = shapes.get(lhs_m.group(1), "")
                lhs_dims = _shape_dims(lhs_shape)
                cdims = [int(x) for x in con_m.group(1).split(",") if x.strip()]
                k = 1
                for c in cdims:
                    if c < len(lhs_dims):
                        k *= lhs_dims[c]
                out_n = 1
                for d in out_dims:
                    out_n *= d
                flops_total += 2.0 * out_n * k * m_comp
                n_dots += 1

    return {
        "collective_bytes": coll_total,
        "collective_by_kind": dict(coll_kind),
        "dot_flops": flops_total,
        "n_dot_sites": n_dots,
        "n_computations": len(comps),
        "while_trips": while_trips,
    }


def collective_bytes(hlo: str) -> Tuple[int, Dict[str, int]]:
    res = analyze_hlo(hlo)
    return int(res["collective_bytes"]), {
        k: int(v) for k, v in res["collective_by_kind"].items()
    }
