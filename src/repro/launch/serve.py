"""Serving driver: batched prefill + decode over sketch-filtered requests.

Demonstrates the inference side of the framework: a request pool carries
metadata (same schema as the corpus); a PBDS sketch filters which requests a
given serving policy ("serve only domains whose mean quality passes tau")
touches, then the model prefills the batch and decodes N tokens.

  PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b --smoke \
      --requests 16 --prompt-len 64 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.data import CurationSpec, make_corpus_metadata
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(args.seed)
    params = lm.concrete_params(key, cfg)

    # --- sketch-filtered admission ------------------------------------------
    meta = make_corpus_metadata(n_docs=5_000, seed=args.seed)
    from repro.data import SketchedDataPipeline

    pipe = SketchedDataPipeline(
        meta, CurationSpec(), args.requests, args.prompt_len, cfg.vocab_size, seed=args.seed
    )
    print(f"[serve] admission sketch on {pipe.run_info.attr}: "
          f"skipping {pipe.skipped_fraction:.1%} of request pool")
    batch_raw = next(iter(pipe))
    tokens = jnp.asarray(batch_raw["tokens"])  # (B, prompt)
    b = tokens.shape[0]

    # --- prefill + greedy decode ---------------------------------------------
    batch = {"tokens": tokens}
    if cfg.frontend == "vision":
        batch["frontend"] = jnp.zeros((b, cfg.n_frontend_tokens, cfg.frontend_dim), jnp.float32)
    if cfg.is_encdec:
        batch["frames"] = jnp.zeros((b, args.prompt_len, cfg.frontend_dim), jnp.float32)

    t0 = time.perf_counter()
    logits = jax.jit(lambda p, bb: lm.prefill(p, cfg, bb))(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    total = args.prompt_len + args.gen
    cache = lm.init_cache(cfg, b, total, cross_len=args.prompt_len if cfg.is_encdec else 0)
    decode = jax.jit(lambda p, c, t, pos: lm.decode_step(p, cfg, c, t, pos))
    # Feed the prompt through the decode path to fill the cache (teacher-forced),
    # then generate greedily.
    tok = tokens[:, 0]
    t0 = time.perf_counter()
    for i in range(total - 1):
        logits, cache = decode(params, cache, tok, jnp.asarray(i, jnp.int32))
        tok = tokens[:, i + 1] if i + 1 < args.prompt_len else jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t0
    per_tok = t_decode / (total - 1)
    print(f"[serve] B={b} prefill({args.prompt_len} tok)={t_prefill*1e3:.0f}ms "
          f"decode={per_tok*1e3:.1f}ms/tok throughput={b/per_tok:.0f} tok/s")
    print(f"[serve] finite logits: {bool(jnp.isfinite(logits).all())}")


if __name__ == "__main__":
    main()
