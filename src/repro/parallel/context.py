"""Activation-sharding context.

The logical-axis rules shard *parameters*; XLA's sharding propagation is then
free to choose activation shardings — and with FSDP-sharded weights it will
happily reshard activations' embed dim onto the 'data' axis (Megatron-style
activation TP) instead of keeping data parallelism, inserting an all-reduce
per norm.  Pinning the batch dim of the residual stream at block boundaries
forces the FSDP schedule: weights all-gather per layer, activations stay DP.

Model code calls ``constrain_batch(x)``; outside a context (smoke tests,
single device) it is a no-op.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

_TLS = threading.local()


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, batch_axes: Any, param_specs: Any = None,
                        seq_axis: Any = None):
    """batch_axes: mesh axis (or tuple) for the leading batch dim, or None.

    ``param_specs``: optional tree (mirroring the model's param tree) of
    *compute* PartitionSpecs — FSDP dims gathered (None), TP dims kept.
    Applied to each period's weights inside the layer scan, this forces the
    ZeRO-3 schedule: weights all-gather per layer; activations stay DP.

    ``seq_axis``: Megatron-style sequence parallelism — the residual stream's
    sequence dim is pinned to this mesh axis between blocks, turning the TP
    activation all-reduces into reduce-scatter + all-gather pairs and running
    norms on sequence shards.
    """
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, batch_axes, param_specs, seq_axis)
    try:
        yield
    finally:
        _TLS.ctx = prev


def current() -> Optional[Tuple[Mesh, Any, Any]]:
    return getattr(_TLS, "ctx", None)


def constrain_state(x: jax.Array) -> jax.Array:
    """Pin a recurrent-state tensor's batch dim to the DP axes, leaving the
    other dims unconstrained (model sharding of inner dims survives).  Used
    on scan-carry INITIAL values: the while-loop carry sharding is decided by
    the init, and an unsharded init means a reshard every step."""
    ctx = current()
    if ctx is None:
        return x
    mesh, ba = ctx[0], ctx[1]
    if ba is None:
        return x
    rest = [PartitionSpec.UNCONSTRAINED] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(ba, *rest))
    )


def constrain_params(path, params: Any) -> Any:
    """Pin a param subtree to its compute sharding.

    ``path``: key or tuple of keys into the compute-spec tree.  Constraining
    at the *innermost* use site (one block, not one period) lets XLA schedule
    the ZeRO-3 all-gathers per block — the transient is one layer's weights,
    not a whole period's (matters for jamba's 8-layer period at 398B).
    """
    ctx = current()
    if ctx is None or ctx[2] is None:
        return params
    mesh, specs = ctx[0], ctx[2]
    sub = specs
    for k in (path if isinstance(path, tuple) else (path,)):
        if not isinstance(sub, dict) or k not in sub:
            return params
        sub = sub[k]
    return jax.tree_util.tree_map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        params,
        sub,
    )


def constrain_batch(x: jax.Array, *, trailing: Optional[Tuple] = None) -> jax.Array:
    """Pin x's dim 0 to the DP axes (and optionally dim 1 to the SP axis)."""
    ctx = current()
    if ctx is None:
        return x
    mesh, ba = ctx[0], ctx[1]
    seq_axis = ctx[3] if len(ctx) > 3 else None
    if trailing is None:
        rest = [None] * (x.ndim - 1)
        if seq_axis is not None and x.ndim >= 3 and x.shape[1] > 1:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            ax = seq_axis if isinstance(seq_axis, tuple) else (seq_axis,)
            import numpy as _np

            if x.shape[1] % int(_np.prod([sizes[a] for a in ax])) == 0:
                rest[0] = seq_axis
        rest = tuple(rest)
    else:
        rest = trailing
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PartitionSpec(ba, *rest))
    )
