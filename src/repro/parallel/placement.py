"""Device placement for fragment shards (``repro.core.shard``).

Shards are host-emulated processes by default: each ``FragmentShard`` is an
in-process object with its own table, catalog, and maintainers.  When the
runtime exposes more than one accelerator (a ``jax`` device mesh), each
shard's columns are pinned to a device round-robin so per-shard partial
aggregation runs on the shard's own accelerator — the same fragment-routing
logic, different executor placement.  On a single-device host everything
lands on the default device and placement is a no-op.
"""
from __future__ import annotations

from typing import List, Optional

import jax

from repro.core.table import ColumnTable


def shard_devices(n_shards: int, use_devices: bool = True) -> List[Optional[jax.Device]]:
    """One device per shard, round-robin over the local devices.

    Returns ``None`` entries (no pinning) when placement is disabled or only
    one device exists — ``jax.device_put`` to the sole default device would
    just add transfer bookkeeping for nothing.
    """
    devices = jax.local_devices()
    if not use_devices or len(devices) <= 1:
        return [None] * n_shards
    return [devices[i % len(devices)] for i in range(n_shards)]


def place_table(table: ColumnTable, device: Optional[jax.Device]) -> ColumnTable:
    """Pin every column of ``table`` to ``device`` (identity when None)."""
    if device is None:
        return table
    cols = {k: jax.device_put(v, device) for k, v in table.columns.items()}
    return ColumnTable(table.name, cols, table.primary_key, table.layout,
                       version=table.version, uid=table.uid)
