"""Device placement for fragment shards (``repro.core.shard``).

Shards are host-emulated processes by default: each ``FragmentShard`` is an
in-process object with its own table, catalog, and maintainers.  When the
runtime exposes more than one accelerator (a ``jax`` device mesh), each
shard's columns are pinned to a device round-robin so per-shard partial
aggregation runs on the shard's own accelerator — the same fragment-routing
logic, different executor placement.  On a single-device host everything
lands on the default device and placement is a no-op.
"""
from __future__ import annotations

from typing import List, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec

from repro.core.table import ColumnTable


def serving_mesh(use_devices: bool = True):
    """The 1-D SPMD serving mesh, or ``None`` (single device / disabled).

    Thin indirection over ``repro.launch.mesh.make_serving_mesh`` so the
    sharded engine's placement decisions all route through this module.
    """
    if not use_devices:
        return None
    from repro.launch.mesh import make_serving_mesh

    return make_serving_mesh()


def place_stacked(arr: jax.Array, mesh, shard_axis: int = 1) -> jax.Array:
    """Pin a stacked shard-major array's shard axis across the mesh.

    ``arr``'s ``shard_axis`` is laid out over the mesh's ``"shards"`` axis
    (every device owns a contiguous run of shard slices) so the fused
    shard_map launch reads its shard's rows locally.  Identity when there is
    no mesh or the axis does not divide evenly (the vmapped single-program
    fallback then runs wherever the arrays already live).
    """
    if mesh is None:
        return arr
    n_dev = mesh.devices.size
    if arr.shape[shard_axis] % n_dev != 0:
        return arr
    spec = [None] * arr.ndim
    spec[shard_axis] = "shards"
    return jax.device_put(arr, NamedSharding(mesh, PartitionSpec(*spec)))


def shard_devices(n_shards: int, use_devices: bool = True) -> List[Optional[jax.Device]]:
    """One device per shard, round-robin over the local devices.

    Returns ``None`` entries (no pinning) when placement is disabled or only
    one device exists — ``jax.device_put`` to the sole default device would
    just add transfer bookkeeping for nothing.
    """
    devices = jax.local_devices()
    if not use_devices or len(devices) <= 1:
        return [None] * n_shards
    return [devices[i % len(devices)] for i in range(n_shards)]


def failover_device(
    devices: List[Optional[jax.Device]],
    sid: int,
    dead: List[int],
) -> Optional[jax.Device]:
    """Placement for shard ``sid``'s post-failover rebuild.

    Keeps the shard's own pin in the common case.  When the same physical
    device also backs *another* dead shard, the fault likely sits with the
    device rather than the shard process, so the rebuild lands on the
    least-loaded device backing no dead shard (falling back to its own pin
    when every device is implicated).  ``None`` pins (single-device hosts)
    stay ``None`` — placement is a no-op there.
    """
    own = devices[sid]
    if own is None:
        return None
    dead_devs = {str(devices[d]) for d in dead
                 if d != sid and devices[d] is not None}
    if str(own) not in dead_devs:
        return own
    alive = [d for d in devices if d is not None and str(d) not in dead_devs]
    if not alive:
        return own
    load: dict = {}
    for d in alive:
        load[str(d)] = load.get(str(d), 0) + 1
    return min(alive, key=lambda d: (load[str(d)], str(d)))


def place_table(table: ColumnTable, device: Optional[jax.Device]) -> ColumnTable:
    """Pin every column of ``table`` to ``device`` (identity when None)."""
    if device is None:
        return table
    cols = {k: jax.device_put(v, device) for k, v in table.columns.items()}
    return ColumnTable(table.name, cols, table.primary_key, table.layout,
                       version=table.version, uid=table.uid)
