"""Distribution: mesh construction + logical-axis sharding rules."""
