"""Logical-axis sharding rules (MaxText-style), divisibility-aware.

Every parameter leaf (``P`` spec) names its dims with logical axes; the rules
below map logical axes to mesh axes.  ``spec_for`` checks divisibility and
never assigns the same mesh axis twice within one PartitionSpec, so any
(config x mesh) combination lowers — heads that don't divide the TP axis
simply replicate (the configs pad where that matters, see DESIGN.md §6).

Parallelism coverage:
  DP    batch dim over ('pod', 'data')
  FSDP  'embed' (+ 'layers' fallback) over ('pod', 'data')  [ZeRO-3]
  TP    'q_heads'/'kv_heads'/'ffn'/'vocab'/'ssm_inner'/... over 'model'
  EP    'experts' over 'model'
  SP    decode KV-cache sequence dim over 'data' when batch can't use it
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

from repro.models.config import ModelConfig, ShapeConfig
from repro.models.params import P, is_leaf, tree_map_p

# logical axis -> candidate mesh axes (first that divides wins; tried in order)
DEFAULT_RULES: Dict[Optional[str], Tuple[Tuple[str, ...], ...]] = {
    "embed": (("pod", "data"), ("data",)),  # FSDP / ZeRO-3
    "vocab": (("model",),),
    "q_heads": (("model",),),
    "kv_heads": (("model",),),
    "ffn": (("model",),),
    "experts": (("model",),),
    "moe_ffn": (),  # training: experts take 'model', embed takes FSDP
    "ssm_inner": (("model",),),
    "xl_inner": (("model",),),
    "units": (("model",),),
    "head_dim": (),
    "layers": (),
    None: (),
}

# Small-arch layout (<~1.5B params): TP over 16 chips makes every matmul's
# activation all-reduce dominate a tiny compute; instead run pure DP over the
# *whole* mesh (batch on pod x data x model) with ZeRO over the same axes.
DP_ONLY_RULES: Dict[Optional[str], Tuple[Tuple[str, ...], ...]] = {
    "embed": (("pod", "data", "model"), ("data", "model"), ("data",)),
    "vocab": (),
    "q_heads": (),
    "kv_heads": (),
    "ffn": (),
    "experts": (),
    "moe_ffn": (),
    "ssm_inner": (),
    "xl_inner": (),
    "units": (),
    "head_dim": (),
    "layers": (),
    None: (),
}


def dp_batch_axes(mesh: Mesh, batch: int) -> Optional[Any]:
    """Densest prefix of ('pod','data','model') dividing the batch."""
    sizes = _mesh_axis_sizes(mesh)
    for axes in (("pod", "data", "model"), ("data", "model"), ("pod", "data"), ("data",)):
        axes = tuple(a for a in axes if a in sizes)
        if axes and batch % int(np.prod([sizes[a] for a in axes])) == 0:
            return axes if len(axes) > 1 else axes[0]
    return None


# Serving layout: NO FSDP — weights never move at decode/prefill.  Hidden
# dims take every available axis instead of the embed dim, so contractions
# stay local and only (tiny) activation partial-sums cross the ICI.
SERVING_RULES: Dict[Optional[str], Tuple[Tuple[str, ...], ...]] = {
    "embed": (),
    "vocab": (("model",),),
    "q_heads": (("model",),),
    "kv_heads": (("model",),),
    "ffn": (("model", "data"), ("model",)),
    "experts": (("model",),),
    "moe_ffn": (("data",),),
    "ssm_inner": (("model", "data"), ("model",)),
    "xl_inner": (("model", "data"), ("model",)),
    "units": (("model",),),
    "head_dim": (),
    "layers": (),
    None: (),
}


def _mesh_axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def spec_for(p: P, mesh: Mesh, rules: Optional[Dict] = None) -> PartitionSpec:
    rules = rules or DEFAULT_RULES
    sizes = _mesh_axis_sizes(mesh)
    used: set = set()
    out = []
    for dim, ax in zip(p.shape, p.axes):
        assigned = None
        for cand in rules.get(ax, ()):  # each cand is a tuple of mesh axes
            cand = tuple(a for a in cand if a in sizes)
            if not cand or any(a in used for a in cand):
                continue
            prod = int(np.prod([sizes[a] for a in cand]))
            if prod > 1 and dim % prod == 0:
                assigned = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
        out.append(assigned)
    return PartitionSpec(*out)


def param_pspecs(spec_tree: Any, mesh: Mesh, rules: Optional[Dict] = None) -> Any:
    return tree_map_p(lambda p: spec_for(p, mesh, rules), spec_tree)


# Compute-time rules: FSDP dims are *gathered* at the point of use (ZeRO-3
# schedule); only true TP axes stay sharded during the matmul.
COMPUTE_RULES: Dict[Optional[str], Tuple[Tuple[str, ...], ...]] = {
    **DEFAULT_RULES,
    "embed": (),
}

# moe2d variant: expert weights are *resharded* (d gathered, ffe sharded over
# 'data') instead of fully gathered — the compute copy is 1/|data| the size
# and the reshard moves ~1/|data| the bytes of an all-gather; the price is an
# activation partial-sum after the down-projection.
MOE2D_COMPUTE_RULES: Dict[Optional[str], Tuple[Tuple[str, ...], ...]] = {
    **COMPUTE_RULES,
    "moe_ffn": (("data",),),
}

# all2d: every hidden dim 2-D sharded at compute — weights reshard (cheap)
# instead of gathering the embed dim; partial-sums on (B,S,d) activations.
ALL2D_COMPUTE_RULES: Dict[Optional[str], Tuple[Tuple[str, ...], ...]] = {
    **MOE2D_COMPUTE_RULES,
    "ffn": (("model", "data"), ("model",)),
    "ssm_inner": (("model", "data"), ("model",)),
}


def compute_pspecs(spec_tree: Any, mesh: Mesh) -> Any:
    """Per-leaf compute PartitionSpecs with the leading stack dim dropped for
    period-stacked leaves (the scan body sees one period's slice)."""

    def leaf(p: P) -> PartitionSpec:
        s = spec_for(p, mesh, COMPUTE_RULES)
        if p.axes and p.axes[0] == "layers":
            return PartitionSpec(*tuple(s)[1:])
        return s

    return tree_map_p(leaf, spec_tree)


def resident_pspecs(spec_tree: Any, mesh: Mesh, rules: Optional[Dict] = None) -> Any:
    """Serving-layout specs with the stack dim dropped: pins weights to where
    they live during compute (no gathers — EP/TP stay put, activations move
    instead)."""
    rules = rules or SERVING_RULES

    def leaf(p: P) -> PartitionSpec:
        s = spec_for(p, mesh, rules)
        if p.axes and p.axes[0] == "layers":
            return PartitionSpec(*tuple(s)[1:])
        return s

    return tree_map_p(leaf, spec_tree)


def param_shardings(spec_tree: Any, mesh: Mesh, rules: Optional[Dict] = None) -> Any:
    return tree_map_p(lambda p: NamedSharding(mesh, spec_for(p, mesh, rules)), spec_tree)


# ---------------------------------------------------------------------------
# Activation / batch / cache shardings
# ---------------------------------------------------------------------------


def batch_axes(mesh: Mesh, batch: int) -> Optional[Any]:
    """Densest prefix of ('pod','data') that divides the batch."""
    sizes = _mesh_axis_sizes(mesh)
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    full = int(np.prod([sizes[a] for a in dp])) if dp else 1
    if dp and batch % full == 0:
        return dp if len(dp) > 1 else dp[0]
    if "data" in sizes and batch % sizes["data"] == 0:
        return "data"
    return None


def token_pspec(mesh: Mesh, batch: int) -> PartitionSpec:
    return PartitionSpec(batch_axes(mesh, batch), None)


def batch_pspecs(mesh: Mesh, abstract_batch: Any, batch_size: int) -> Any:
    """Shardings for the training/prefill input dict (tokens/frontend/frames)."""
    ba = batch_axes(mesh, batch_size)

    def leaf(x):
        return PartitionSpec(ba, *([None] * (x.ndim - 1)))

    return jax.tree_util.tree_map(leaf, abstract_batch)


def cache_pspecs(cfg: ModelConfig, mesh: Mesh, abstract_cache: Any, batch: int) -> Any:
    """KV/state cache shardings.

    Batch shards over DP axes when divisible.  When it is not (long_500k has
    batch 1) the *sequence* dim of attention caches shards over 'data'
    instead — sequence parallelism for decode.  Head/inner dims shard over
    'model' when divisible.
    """
    sizes = _mesh_axis_sizes(mesh)
    ba = batch_axes(mesh, batch)
    model = sizes.get("model", 1)
    data = sizes.get("data", 1)

    def leaf(path, x):
        keys = [getattr(k, "key", getattr(k, "name", None)) for k in path]
        keys = [k for k in keys if isinstance(k, str)]
        # Leading dim may be the period-stack; detect via parent 'periods'.
        stacked = "periods" in keys
        lead = (None,) if stacked else ()
        shape = x.shape[1:] if stacked else x.shape
        kind = None
        for k in ("kv", "xkv", "ssm", "ml", "sl"):
            if k in keys:
                kind = k
        name = keys[-1] if keys else ""
        if kind in ("kv", "xkv") and len(shape) == 4:
            b, t, h, hd = shape
            used = set()
            if ba is not None:
                used.update(ba if isinstance(ba, tuple) else (ba,))
            head_ax = "model" if h % model == 0 and model > 1 else None
            if head_ax:
                used.add("model")
            # Sequence parallelism for the cache: shard seq over every axis
            # not already carrying batch/heads (long-context decode, and GQA
            # archs whose few KV heads can't fill the model axis).
            seq_axes = []
            seq_div = 1
            for a in ("data", "model"):
                if a in sizes and a not in used and t % (seq_div * sizes[a]) == 0:
                    seq_axes.append(a)
                    seq_div *= sizes[a]
            seq_ax = tuple(seq_axes) if len(seq_axes) > 1 else (seq_axes[0] if seq_axes else None)
            return PartitionSpec(*lead, ba, seq_ax, head_ax, None)
        if kind == "ssm" and name == "h" and len(shape) == 3:
            return PartitionSpec(*lead, ba, "model" if shape[1] % model == 0 else None, None)
        if kind == "ssm" and name == "conv" and len(shape) == 3:
            return PartitionSpec(*lead, ba, None, "model" if shape[2] % model == 0 else None)
        # xLSTM states & anything else: shard batch only.
        return PartitionSpec(*lead, ba, *([None] * (len(shape) - 1)))

    return jax.tree_util.tree_map_with_path(leaf, abstract_cache)


def to_shardings(mesh: Mesh, pspec_tree: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s),
        pspec_tree,
        is_leaf=lambda x: isinstance(x, PartitionSpec),
    )
