"""AdamW with cosine schedule, global-norm clipping, and ZeRO-compatible
state: the first/second moments (and optional fp32 master copy) carry the
same logical axes as their parameters, so the FSDP+TP sharding rules shard
optimizer state across the full mesh automatically (ZeRO-1/3 hybrid).

``opt_dtype='bfloat16'`` halves optimizer memory for the 398B cell; the
update math always runs in f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    opt_dtype: str = "float32"  # m/v dtype
    use_master: bool = True  # keep fp32 master copy of bf16 params


def schedule(oc: OptConfig, step: Array) -> Array:
    warm = jnp.minimum(step / jnp.maximum(oc.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - oc.warmup_steps) / jnp.maximum(oc.total_steps - oc.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return oc.lr * warm * (0.1 + 0.9 * cos)


def init_opt_state(params: Any, oc: OptConfig) -> Dict[str, Any]:
    dt = jnp.dtype(oc.opt_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    state = {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }
    if oc.use_master:
        state["master"] = jax.tree_util.tree_map(
            lambda p: p.astype(jnp.float32), params
        )
    return state


def abstract_opt_state(abstract_parms: Any, oc: OptConfig) -> Dict[str, Any]:
    dt = jnp.dtype(oc.opt_dtype)
    sd = lambda p, d: jax.ShapeDtypeStruct(p.shape, d)
    state = {
        "m": jax.tree_util.tree_map(lambda p: sd(p, dt), abstract_parms),
        "v": jax.tree_util.tree_map(lambda p: sd(p, dt), abstract_parms),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    if oc.use_master:
        state["master"] = jax.tree_util.tree_map(
            lambda p: sd(p, jnp.float32), abstract_parms
        )
    return state


def global_norm(tree: Any) -> Array:
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree_util.tree_leaves(tree)
    )
    return jnp.sqrt(sq)


def adamw_update(
    grads: Any, opt_state: Dict[str, Any], params: Any, oc: OptConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, Array]]:
    """One AdamW step.  Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(oc, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, oc.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = oc.b1, oc.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    dt = jnp.dtype(oc.opt_dtype)
    source = opt_state.get("master", params)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        mh = m32 / bc1
        vh = v32 / bc2
        p32 = p.astype(jnp.float32)
        new_p = p32 - lr * (mh / (jnp.sqrt(vh) + oc.eps) + oc.weight_decay * p32)
        return new_p, m32.astype(dt), v32.astype(dt)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = jax.tree_util.tree_flatten(opt_state["m"])[0]
    flat_v = jax.tree_util.tree_flatten(opt_state["v"])[0]
    flat_p = jax.tree_util.tree_flatten(source)[0]
    flat_pd = jax.tree_util.tree_flatten(params)[0]
    new_p32, new_m, new_v = [], [], []
    for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p):
        a, b, c = upd(g, m, v, p)
        new_p32.append(a)
        new_m.append(b)
        new_v.append(c)

    param_dtype = flat_pd[0].dtype
    new_params = jax.tree_util.tree_unflatten(
        treedef, [p.astype(param_dtype) for p in new_p32]
    )
    new_state = {
        "m": jax.tree_util.tree_unflatten(treedef, new_m),
        "v": jax.tree_util.tree_unflatten(treedef, new_v),
        "step": step,
    }
    if "master" in opt_state:
        new_state["master"] = jax.tree_util.tree_unflatten(treedef, new_p32)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
