from repro.optim.adamw import OptConfig, abstract_opt_state, adamw_update, init_opt_state
