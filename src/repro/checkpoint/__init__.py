from repro.checkpoint.checkpoint import CheckpointManager
