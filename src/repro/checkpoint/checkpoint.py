"""Fault-tolerant checkpointing.

Design points for 1000+-node runs:
  - atomic publish: write to ``step_N.tmp/`` then ``os.replace`` to ``step_N/``
    (a crashed writer never corrupts the latest checkpoint);
  - per-host shard files: each host serializes only the addressable shards of
    its arrays (here: the whole array on 1 host), so restore scales O(1/host);
  - keep-last-k GC + a ``latest`` pointer written last;
  - async save: the step thread snapshots device arrays to host memory, a
    background thread does the IO (training continues);
  - the data-pipeline iterator state is stored alongside the model state so
    restart resumes mid-epoch without replaying or skipping batches.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, state: Any, extra: Optional[Dict[str, Any]] = None) -> None:
        leaves, treedef = _flatten(state)
        # Snapshot to host *synchronously* (cheap), do IO async.  Non-native
        # dtypes (bf16/f8) upcast to f32 for .npz portability; restore casts
        # back to the reference dtype.
        _NATIVE = {
            "bool", "int8", "int16", "int32", "int64", "uint8", "uint16",
            "uint32", "uint64", "float16", "float32", "float64",
            "complex64", "complex128",
        }

        def to_host(x):
            a = np.asarray(x)
            if str(a.dtype) not in _NATIVE:
                a = np.asarray(jax.numpy.asarray(x).astype(jax.numpy.float32))
            return a

        host_leaves = [to_host(x) for x in leaves]
        if self._thread is not None:
            self._thread.join()  # one outstanding save at a time

        def _write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "shard_host0.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            meta = {
                "step": step,
                "n_leaves": len(host_leaves),
                "extra": extra or {},
                "time": time.time(),
            }
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
            with open(os.path.join(self.dir, "latest.tmp"), "w") as f:
                f.write(str(step))
            os.replace(os.path.join(self.dir, "latest.tmp"), os.path.join(self.dir, "latest"))
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        p = os.path.join(self.dir, "latest")
        if os.path.exists(p):
            with open(p) as f:
                s = int(f.read().strip())
            if os.path.exists(os.path.join(self.dir, f"step_{s}")):
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: Optional[int] = None) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure (and shardings) of ``like``."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "meta.json")) as f:
            meta = json.load(f)
        data = np.load(os.path.join(d, "shard_host0.npz"))
        leaves, treedef = _flatten(like)
        out = []
        for i, ref in enumerate(leaves):
            arr = jax.numpy.asarray(data[f"leaf_{i}"]).astype(ref.dtype)
            if hasattr(ref, "sharding"):
                arr = jax.device_put(arr, ref.sharding)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out), meta["extra"]
