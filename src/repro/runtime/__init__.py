from repro.runtime.elastic import (
    ElasticPlan,
    feasible_mesh_shape,
    plan_remesh,
)
from repro.runtime.resilience import RetryPolicy, StragglerMonitor, with_retries
