from repro.runtime.chaos import (
    ChaosEvent,
    ChaosHarness,
    differential,
    random_ops,
    random_schedule,
    run_ops,
)
from repro.runtime.elastic import (
    ElasticPlan,
    feasible_mesh_shape,
    plan_remesh,
    plan_replacement,
)
from repro.runtime.resilience import RetryPolicy, StragglerMonitor, with_retries
