from repro.runtime.guards import (
    LAUNCH_COUNTS,
    TRACE_COUNTS,
    GuardViolation,
    LaunchCountError,
    RetraceError,
    hot_path,
    launch_guard,
    retrace_guard,
    sanitize_enabled,
    sanitized,
    tracer_leak_guard,
    transfer_guard,
)
from repro.runtime.stable_hash import canonical_repr, stable_hash32
from repro.runtime.chaos import (
    ChaosEvent,
    ChaosHarness,
    differential,
    random_ops,
    random_schedule,
    run_ops,
)
from repro.runtime.elastic import (
    ElasticPlan,
    feasible_mesh_shape,
    plan_remesh,
    plan_replacement,
)
from repro.runtime.resilience import RetryPolicy, StragglerMonitor, with_retries
