"""Deterministic fault injection for chaos-testing the sharded engine.

The harness replays a workload (queries, batches, appends, deletes) against a
``ShardedEngine`` while injecting faults into its shards at scripted or
seeded-random points: ``kill`` (all local state lost — a SIGKILL of the
server process on the subprocess backend), ``stall`` (every op sleeps — a
straggler), ``partition`` (unreachable, state intact — a dropped socket),
``flaky`` (the next N ops fail, then self-heal — injected RPC errors), and
``heal``.

Everything is seeded and replayable: ``random_schedule`` and ``random_ops``
derive all randomness from ``numpy.random.default_rng(seed)``, and delete
masks are carried as ``(seed, fraction)`` pairs resolved against the
engine's current row count — two engines replaying the same op list see
bit-identical mutations, which is what makes the chaos *differential* gate
possible: a chaotic replay must produce results equal to the fault-free
replay of the same ops (degraded-mode substitution is bit-identical under
the exactness envelope, so equality is exact, not approximate).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

#: Fault kinds ``random_schedule`` draws from (``heal`` is scheduled
#: separately so faults actually get cleared and recovery paths run).
FAULT_KINDS = ("kill", "stall", "partition", "flaky")

#: Coordinator-level fault kinds (``coord_rate``): ``coord_kill`` drops the
#: active coordinator dead (standby takeover adopts replicated metadata),
#: ``coord_partition`` fences it off while it still *thinks* it is the
#: coordinator — the epoch fence is what keeps its zombie ops out.
COORD_FAULT_KINDS = ("coord_kill", "coord_partition")

#: ``ChaosEvent.shard`` sentinel for coordinator-level events.
COORD = -1


@dataclasses.dataclass(frozen=True)
class ChaosEvent:
    """One scripted fault transition, applied just before op ``step``."""

    step: int
    shard: int
    kind: str  # one of FAULT_KINDS, or "heal"
    arg: Optional[float] = None  # stall seconds / flaky op count


def random_schedule(
    seed: int,
    n_steps: int,
    n_shards: int,
    rate: float = 0.35,
    stall_s: float = 0.005,
    heal_bias: float = 0.5,
    coord_rate: float = 0.0,
) -> List[ChaosEvent]:
    """A seeded-random fault schedule over ``n_steps`` workload ops.

    At each step, with probability ``rate``, either heal one currently
    faulted shard (probability ``heal_bias`` when any is faulted — keeps
    kill/rejoin cycles flowing so recovery actually executes) or inject a
    fresh fault on a healthy shard.  The tail of the schedule heals every
    outstanding fault so a replay can end with a fully recovered cluster.

    With ``coord_rate > 0`` the schedule additionally drops coordinator
    faults (``COORD_FAULT_KINDS`` on the ``COORD`` sentinel shard) — each
    one forces a standby takeover mid-replay.  Coordinator faults compose
    freely with shard faults: a takeover must work while shards are dead,
    stalled, or partitioned.
    """
    rng = np.random.default_rng(seed)
    faulted: Dict[int, str] = {}
    events: List[ChaosEvent] = []
    for step in range(n_steps):
        if coord_rate > 0 and rng.random() < coord_rate:
            kind = COORD_FAULT_KINDS[int(rng.integers(len(COORD_FAULT_KINDS)))]
            events.append(ChaosEvent(step, COORD, kind))
        if rng.random() >= rate:
            continue
        if faulted and (rng.random() < heal_bias or len(faulted) == n_shards):
            shard = sorted(faulted)[int(rng.integers(len(faulted)))]
            del faulted[shard]
            events.append(ChaosEvent(step, shard, "heal"))
            continue
        free = [s for s in range(n_shards) if s not in faulted]
        if not free:
            continue
        shard = free[int(rng.integers(len(free)))]
        kind = FAULT_KINDS[int(rng.integers(len(FAULT_KINDS)))]
        if kind == "stall":
            events.append(ChaosEvent(step, shard, "stall", stall_s))
            faulted[shard] = kind
        elif kind == "flaky":
            # Self-heals after failing the next 1-3 ops; not tracked as
            # persistently faulted.
            events.append(ChaosEvent(step, shard, "flaky",
                                     float(rng.integers(1, 4))))
        else:
            events.append(ChaosEvent(step, shard, kind))
            faulted[shard] = kind
    for shard in sorted(faulted):
        events.append(ChaosEvent(n_steps - 1, shard, "heal"))
    return events


def random_ops(
    seed: int,
    n_steps: int,
    queries: Sequence,
    make_rows: Callable[[np.random.Generator, int], Dict[str, np.ndarray]],
    p_query: float = 0.45,
    p_batch: float = 0.2,
    p_append: float = 0.2,
    delete_frac: float = 0.02,
) -> List[Tuple[str, object]]:
    """A seeded workload: single queries, query batches, appends, deletes.

    Ops are engine-independent values — append batches are materialized row
    dicts, deletes are ``(seed, fraction)`` resolved at replay time — so the
    same list replays identically against any number of engines.
    """
    rng = np.random.default_rng(seed)
    ops: List[Tuple[str, object]] = []
    for _ in range(n_steps):
        r = rng.random()
        if r < p_query:
            ops.append(("query", queries[int(rng.integers(len(queries)))]))
        elif r < p_query + p_batch:
            ops.append(("batch", [
                queries[int(rng.integers(len(queries)))]
                for _ in range(int(rng.integers(2, 5)))]))
        elif r < p_query + p_batch + p_append:
            rows = make_rows(rng, int(rng.integers(40, 160)))
            ops.append(("append", {k: np.asarray(v) for k, v in rows.items()}))
        else:
            ops.append(("delete", (int(rng.integers(1 << 31)), delete_frac)))
    return ops


def run_ops(
    engine,
    table: str,
    ops: Sequence[Tuple[str, object]],
    on_step: Optional[Callable[[int], None]] = None,
) -> List:
    """Replay one op list; returns the canonical result trace.

    Query results enter the trace in canonical form (sorted group tuples),
    mutations as ``(kind, #rows)`` markers — the trace is the object the
    differential gate compares with ``==``.  No exception handling here on
    purpose: the engine is REQUIRED to keep answering through faults, so
    anything surfacing to this loop is a finding.
    """
    trace: List = []
    for step, (kind, payload) in enumerate(ops):
        if on_step is not None:
            on_step(step)
        if kind == "query":
            res, _ = engine.run(payload)
            trace.append(res.canonical())
        elif kind == "batch":
            outs = engine.run_batch(list(payload))
            trace.append(tuple(r.canonical() for r, _ in outs))
        elif kind == "append":
            engine.append_rows(table, payload)
            n = next(iter(payload.values())).shape[0]
            trace.append(("append", int(n)))
        elif kind == "delete":
            dseed, frac = payload
            mask = (np.random.default_rng(dseed).random(
                engine.db[table].num_rows) < frac)
            engine.delete_rows(table, mask)
            trace.append(("delete", int(mask.sum())))
        else:  # pragma: no cover - defensive
            raise ValueError(f"unknown op kind {kind!r}")
    return trace


class ChaosHarness:
    """Applies a fault schedule while replaying a workload.

    The harness pokes faults through the engine's shard clients —
    ``inject``/``heal`` on a loopback client flips in-process flags, on a
    subprocess client it delivers the real mechanism (``kill`` SIGKILLs the
    shard server, ``stall`` makes it sleep per op, ``partition`` drops the
    socket, ``flaky`` injects RPC error responses) — and otherwise drives
    the engine through its public serving API only.
    """

    def __init__(self, events: Sequence[ChaosEvent]):
        self.events = list(events)
        self._by_step: Dict[int, List[ChaosEvent]] = {}
        for e in self.events:
            self._by_step.setdefault(e.step, []).append(e)

    def apply_events(self, engine, step: int) -> None:
        for e in self._by_step.get(step, []):
            if e.shard == COORD or e.kind in COORD_FAULT_KINDS:
                # Coordinator-level fault: the engine must be failover-
                # capable (``core.standby.FailoverCoordinator``).
                engine.inject_coord(e.kind)
                continue
            shard = engine.shards[e.shard]
            if e.kind == "heal":
                shard.heal()
            else:
                shard.inject(e.kind, e.arg)

    def run(self, engine, table: str, ops: Sequence[Tuple[str, object]]) -> List:
        return run_ops(engine, table, ops,
                       on_step=lambda s: self.apply_events(engine, s))


def differential(
    make_engine: Callable[[], object],
    table: str,
    ops: Sequence[Tuple[str, object]],
    events: Sequence[ChaosEvent],
    make_clean: Optional[Callable[[], object]] = None,
) -> Tuple[bool, List, List]:
    """The chaos differential gate for one replay sequence.

    Runs the op list fault-free on one fresh engine and under the fault
    schedule on another; returns ``(identical, chaotic_trace, clean_trace)``.
    Identity is exact (``==`` on canonical traces): degraded-mode serving
    substitutes coordinator-side slices that are bit-identical to the lost
    shard's, so chaos may change *routing* but never *results*.

    ``make_clean`` lets the fault-free reference come from a different
    engine configuration than the chaotic run — the cross-backend gate
    (subprocess shards under real kills/stalls/socket drops vs fault-free
    in-process fused serving) uses exactly this.  Engines exposing
    ``shutdown()`` are shut down before returning, so subprocess-backed
    replays never leak shard servers.
    """

    def _run(factory, trace_fn):
        eng = factory()
        try:
            return trace_fn(eng)
        finally:
            close = getattr(eng, "shutdown", None)
            if close is not None:
                close()

    clean = _run(make_clean or make_engine,
                 lambda e: run_ops(e, table, ops))
    chaotic = _run(make_engine,
                   lambda e: ChaosHarness(events).run(e, table, ops))
    return chaotic == clean, chaotic, clean
