"""Elastic scaling: re-mesh planning after node loss/addition.

On real fleets a failed host removes a slice of devices; the runtime must
pick a new (pod, data, model) factorization, re-shard the latest checkpoint,
and resume.  The planning logic is pure and fully unit-tested here; the IO
path reuses CheckpointManager (restore accepts any target sharding, so
re-sharding on restore is free).

Policy: keep the TP ('model') extent unchanged if possible — TP extent is
baked into padded head/expert counts — and shrink/grow the DP axes; global
batch is preserved by rescaling grad-accumulation microbatches.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    n_micro: int  # new grad-accum factor preserving global batch
    dropped_devices: int


def feasible_mesh_shape(
    n_devices: int, model_parallel: int, prefer_pods: int = 1
) -> Optional[Tuple[int, ...]]:
    """Largest (pod, data, model) grid with data*model*pod <= n_devices."""
    if n_devices < model_parallel:
        return None
    usable = n_devices - (n_devices % model_parallel)
    dp_total = usable // model_parallel
    if dp_total == 0:
        return None
    pods = prefer_pods
    while pods > 1 and dp_total % pods != 0:
        pods -= 1
    data = dp_total // pods
    if pods > 1:
        return (pods, data, model_parallel)
    return (data, model_parallel)


def plan_remesh(
    n_devices: int,
    model_parallel: int,
    global_batch: int,
    old_n_micro: int,
    old_data_extent: int,
    prefer_pods: int = 1,
) -> Optional[ElasticPlan]:
    shape = feasible_mesh_shape(n_devices, model_parallel, prefer_pods)
    if shape is None:
        return None
    names = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
    data_extent = shape[-2] * (shape[0] if len(shape) == 3 else 1)
    # Preserve the global batch: per-device batch fixed => n_micro scales
    # inversely with the DP extent.
    n_micro = max(1, old_n_micro * old_data_extent // max(data_extent, 1))
    while n_micro < global_batch and global_batch % n_micro != 0:
        n_micro += 1
    while (global_batch // n_micro) % data_extent != 0 and n_micro < global_batch:
        n_micro += 1
        while global_batch % n_micro != 0 and n_micro < global_batch:
            n_micro += 1
    used = 1
    for s in shape:
        used *= s
    return ElasticPlan(
        mesh_shape=shape,
        axis_names=names,
        n_micro=n_micro,
        dropped_devices=n_devices - used,
    )


def plan_replacement(
    sizes: np.ndarray,
    owner: np.ndarray,
    n_shards: int,
    dead: Sequence[int],
) -> np.ndarray:
    """Re-place the fragments owned by ``dead`` shards onto survivors.

    The fragment-level analogue of ``plan_remesh``: when a shard is lost for
    good, its fragments (sized in rows) are handed to the least-loaded
    surviving shards, largest orphan first — a greedy longest-processing-time
    assignment that keeps the post-failure load spread within one fragment of
    balanced.  Surviving shards keep every fragment they already own (their
    local tables stay valid; only receivers rebuild), and the function is
    pure and deterministic so the coordinator and any observer agree on the
    new placement without coordination.

    Returns the new ``owner`` array; raises ``ValueError`` when every shard
    is dead.
    """
    sizes = np.asarray(sizes, dtype=np.float64)
    owner = np.asarray(owner, dtype=np.int64).copy()
    dead_set = {int(d) for d in dead}
    survivors = [s for s in range(n_shards) if s not in dead_set]
    if not survivors:
        raise ValueError("no surviving shards to re-place fragments on")
    load = {s: float(sizes[owner == s].sum()) for s in survivors}
    orphans = np.nonzero(np.isin(owner, list(dead_set)))[0]
    for f in sorted(orphans.tolist(), key=lambda f: -sizes[f]):
        s = min(survivors, key=lambda s: (load[s], s))
        owner[f] = s
        load[s] += float(sizes[f])
    return owner
