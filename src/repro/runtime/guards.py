"""Runtime sanitizer layer: the invariants the hot path rests on, enforced.

Every fast path this repo ships rests on invariants the type system cannot
see: pow2-padded shapes so steady-state reuse compiles zero XLA programs,
PRNG keys never reused across passes, one launch per served hit batch,
device values never synced mid-loop.  ``tools.analyze`` checks what a static
pass can see at review time; this module is the *runtime* half — shared
telemetry counters and guard context managers that turn "should never
happen" into a raised error in tests.

Telemetry
---------
``TRACE_COUNTS``
    Bumped inside jitted bodies, so the count moves at *trace* time only.
    Tests assert pow2 quantization keeps shape drift inside one compiled
    size class (``core/shard._fused_body``, ``aqp/size_estimation``'s
    incidence pass both count here).
``LAUNCH_COUNTS``
    Bumped once per host-side invocation of a fused launch; tests assert
    the hit path costs exactly one launch per batch.

Guards (each usable standalone; ``sanitized()`` composes them and is a
no-op unless ``REPRO_SANITIZE=1``):
``retrace_guard(allowed=0)``
    Counts real XLA backend compilations inside the block (cached
    executions emit no event) and raises :class:`RetraceError` when more
    than ``allowed`` happen — the shared replacement for the ad-hoc
    compile-listener fixtures the admission/shard/catalog suites grew.
``launch_guard(name, expect=n)``
    Asserts exactly ``n`` host-side launches of counter ``name`` ran.
``transfer_guard(level)``
    Thin wrapper over ``jax.transfer_guard`` — ``"disallow"`` inside a
    device-only region turns a silent host sync into an error.
``tracer_leak_guard()``
    ``jax.checking_leaks()`` — a traced value escaping its trace (the bug
    class behind stale-closure retrace bombs) raises instead of leaking.

``@hot_path`` marks serving-critical entry points.  It is free at runtime
(tags the function and records its qualname); its real consumer is
``tools.analyze``, whose SYNC01/PAD01 rules walk the call graph from the
decorated roots.
"""
from __future__ import annotations

import collections
import contextlib
import os
from typing import Callable, Iterator, List, Optional, TypeVar

F = TypeVar("F", bound=Callable)

# Shared telemetry: one namespace for every hot-path counter (keys are
# owned by the bumping module, e.g. "fused_partials", "incidence_pass").
TRACE_COUNTS: collections.Counter = collections.Counter()
LAUNCH_COUNTS: collections.Counter = collections.Counter()

# Qualified names registered by @hot_path, in registration order.
HOT_PATHS: List[str] = []


def hot_path(fn: F) -> F:
    """Mark ``fn`` as a serving-critical hot path.

    Zero runtime cost (no wrapper): sets ``__hot_path__`` and records the
    qualified name so tooling — and humans reading the code — know the
    function is subject to the hot-path invariants (no host-device sync,
    pow2-padded shapes, no per-call retraces).  ``tools.analyze`` discovers
    the decorator syntactically, so decorating never imports the analyzer.
    """
    HOT_PATHS.append(f"{fn.__module__}.{fn.__qualname__}")
    fn.__hot_path__ = True  # type: ignore[attr-defined]
    return fn


class GuardViolation(AssertionError):
    """A runtime sanitizer guard tripped."""


class RetraceError(GuardViolation):
    """More XLA backend compilations than the guarded block allows."""


class LaunchCountError(GuardViolation):
    """A guarded block launched a different number of times than expected."""


class CompileWatch:
    """Live view of backend compilations inside a ``retrace_guard`` block."""

    def __init__(self) -> None:
        self.events: List[str] = []

    @property
    def compiles(self) -> int:
        return len(self.events)


@contextlib.contextmanager
def retrace_guard(allowed: Optional[int] = 0, label: str = "") -> Iterator[CompileWatch]:
    """Fail when the block compiles more than ``allowed`` XLA programs.

    ``allowed=None`` only observes (use the yielded :class:`CompileWatch`
    to assert that warmup *did* compile).  Counts real backend
    compilations — tracing that hits the executable cache emits no event —
    which is exactly the "steady state compiles nothing new" contract the
    pow2 padding exists to uphold.
    """
    from jax._src import monitoring

    watch = CompileWatch()

    def listener(name: str, duration_secs: float, **kw) -> None:
        if name == "/jax/core/compile/backend_compile_duration":
            watch.events.append(name)

    monitoring.register_event_duration_secs_listener(listener)
    try:
        yield watch
    finally:
        monitoring._unregister_event_duration_listener_by_callback(listener)
    if allowed is not None and watch.compiles > allowed:
        where = f" [{label}]" if label else ""
        raise RetraceError(
            f"retrace_guard{where}: {watch.compiles} XLA compilation(s), "
            f"allowed {allowed} — a hot path left its compiled size class")


class LaunchWatch:
    """Live view of one counter's delta inside a ``launch_guard`` block."""

    def __init__(self, counter: collections.Counter, name: str) -> None:
        self._counter = counter
        self._name = name
        self._before = counter[name]

    @property
    def launches(self) -> int:
        return self._counter[self._name] - self._before


@contextlib.contextmanager
def launch_guard(
    name: str,
    expect: Optional[int] = None,
    counter: Optional[collections.Counter] = None,
) -> Iterator[LaunchWatch]:
    """Watch ``LAUNCH_COUNTS[name]`` over the block; with ``expect`` set,
    fail unless exactly that many launches ran (the "one launch per served
    batch" contract)."""
    watch = LaunchWatch(LAUNCH_COUNTS if counter is None else counter, name)
    yield watch
    if expect is not None and watch.launches != expect:
        raise LaunchCountError(
            f"launch_guard[{name}]: {watch.launches} launch(es), expected {expect}")


@contextlib.contextmanager
def transfer_guard(level: str = "disallow") -> Iterator[None]:
    """``jax.transfer_guard`` over the block: ``"disallow"`` makes any
    implicit host<->device transfer (``float(x)``, ``np.asarray(x)`` on a
    traced/device value) raise instead of silently syncing."""
    import jax

    with jax.transfer_guard(level):
        yield


@contextlib.contextmanager
def tracer_leak_guard() -> Iterator[None]:
    """``jax.checking_leaks()`` over the block: a tracer escaping its trace
    raises at the leak site instead of detonating at next use."""
    import jax

    with jax.checking_leaks():
        yield


def sanitize_enabled() -> bool:
    """True when the sanitizer-enabled test mode is on (``REPRO_SANITIZE=1``)."""
    return os.environ.get("REPRO_SANITIZE", "") not in ("", "0")


@contextlib.contextmanager
def sanitized(
    allowed_compiles: Optional[int] = None,
    transfer: Optional[str] = "disallow",
    leaks: bool = True,
    label: str = "",
) -> Iterator[Optional[CompileWatch]]:
    """The combined sanitizer for device-only regions of tests.

    No-op unless ``REPRO_SANITIZE=1`` (the CI static-analysis job sets it),
    so the guarded suites run everywhere and get teeth in sanitizer mode:
    tracer-leak checking, an implicit-transfer guard, and (when
    ``allowed_compiles`` is not None) a retrace guard.
    """
    if not sanitize_enabled():
        yield None
        return
    with contextlib.ExitStack() as stack:
        if leaks:
            stack.enter_context(tracer_leak_guard())
        if transfer is not None:
            stack.enter_context(transfer_guard(transfer))
        watch = None
        if allowed_compiles is not None:
            watch = stack.enter_context(retrace_guard(allowed_compiles, label=label))
        yield watch
