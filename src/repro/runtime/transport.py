"""Shard transport: length-prefixed message framing over stream sockets.

The serving layer's process boundary.  ``repro.core.shard`` routes every
shard op through a ``ShardClient``; the subprocess backend
(``repro.core.shard_rpc``) carries those ops over a unix-domain socket using
the frame codec here.  Design constraints, in order:

1. **Zero-copy-ish array payloads.**  Messages are pickled with protocol 5
   and out-of-band buffers, so ``TableDelta`` row batches, sketch bit
   vectors, and per-shard partial-aggregate tensors travel as raw buffer
   frames after a small pickle header — no base64, no per-element
   serialization.  ``jax.Array`` values are transparently lowered to host
   ``numpy`` at pickling time (the serialization point IS the host sync;
   the receiving side re-devices lazily on first use).
2. **Per-op deadlines.**  Every send/recv takes a deadline in seconds and
   raises ``RpcTimeout`` when the peer does not complete the transfer in
   time — the subprocess client maps that onto the serving layer's
   ``ShardUnavailableError`` so the PR 6 health machine sees a real stall
   exactly like an injected one.
3. **Bounded frames.**  A frame larger than ``max_frame_bytes`` is refused
   before allocation on the receive side and refused before send on the
   send side — a corrupt length prefix cannot OOM the coordinator, and a
   runaway payload fails loudly at the boundary it crossed.

Framing (all integers big-endian):

    magic  4s   b"RPS1"
    seq    u64  request/response correlation id
    nbufs  u32  number of out-of-band buffers
    crc    u32  crc32 over the length table and every payload part
    lens   u64 * (nbufs + 1)   pickle byte-length, then each buffer's
    pickle bytes
    buffer bytes ...

The crc pins frame *integrity*: a flipped bit anywhere in the lengths or
payload surfaces as ``FrameError`` at the boundary — which the RPC layer
maps to ``ShardUnavailableError`` — instead of a corrupt pickle exploding
arbitrarily deep in the op loop.

The codec is symmetric: servers and clients share ``send_msg``/``recv_msg``.
"""
from __future__ import annotations

import io
import pickle
import socket
import struct
import time
import zlib
from typing import Any, List, Optional, Tuple

import numpy as np

MAGIC = b"RPS1"
_HDR = struct.Struct("!4sQII")  # magic, seq, nbufs, crc32

#: Refuse frames beyond this size (64 MiB default): a corrupted length
#: prefix must not turn into an unbounded allocation.
MAX_FRAME_BYTES = 64 << 20


class TransportError(RuntimeError):
    """Base class for transport failures."""


class RpcTimeout(TransportError):
    """The peer did not complete the transfer inside the deadline."""


class RpcClosed(TransportError):
    """The connection was closed (EOF / reset / broken pipe) mid-message."""


class FrameError(TransportError):
    """Malformed or oversized frame — a protocol violation, not a fault."""


class RemoteError(TransportError):
    """An exception raised on the server whose type could not be mapped
    back to a local class; carries the remote type name and message."""

    def __init__(self, remote_type: str, message: str):
        super().__init__(f"{remote_type}: {message}")
        self.remote_type = remote_type
        self.remote_message = message


# ---------------------------------------------------------------------------
# Codec: pickle protocol 5 with out-of-band buffers, jax -> numpy lowering
# ---------------------------------------------------------------------------


def _is_jax_array(obj: Any) -> bool:
    # Imported lazily so the transport stays usable (and testable) in
    # processes that never touch jax.
    mod = getattr(type(obj), "__module__", "") or ""
    if not (mod.startswith("jax") or mod.startswith("jaxlib")):
        return False
    import jax

    return isinstance(obj, jax.Array)


class _Pickler(pickle.Pickler):
    """Protocol-5 pickler that lowers ``jax.Array`` to host ``numpy``.

    Device arrays are not picklable (and should not be: the peer has its
    own devices).  Lowering at the boundary makes the host sync explicit
    and single-sited; everything else rides the default reducers, with
    numpy emitting out-of-band ``PickleBuffer`` frames under protocol 5.
    """

    def reducer_override(self, obj):
        if _is_jax_array(obj):
            host = np.ascontiguousarray(np.asarray(obj))
            return host.__reduce_ex__(5)
        return NotImplemented


def encode_message(obj: Any) -> List[memoryview]:
    """Encode one message into [pickle bytes, buffer, buffer, ...]."""
    buffers: List[pickle.PickleBuffer] = []
    bio = io.BytesIO()
    _Pickler(bio, protocol=5, buffer_callback=buffers.append).dump(obj)
    out: List[memoryview] = [bio.getbuffer()]
    for b in buffers:
        out.append(b.raw())
    return out


def decode_message(parts: List[bytes]) -> Any:
    """Inverse of ``encode_message``."""
    return pickle.loads(parts[0], buffers=[pickle.PickleBuffer(p)
                                           for p in parts[1:]])


# ---------------------------------------------------------------------------
# Socket send/recv with deadlines
# ---------------------------------------------------------------------------


def _remaining(deadline_at: Optional[float]) -> Optional[float]:
    if deadline_at is None:
        return None
    rem = deadline_at - time.perf_counter()
    if rem <= 0:
        raise RpcTimeout("deadline exhausted")
    return rem


def _sendall(sock: socket.socket, view: memoryview,
             deadline_at: Optional[float]) -> None:
    sent = 0
    try:
        while sent < len(view):
            sock.settimeout(_remaining(deadline_at))
            sent += sock.send(view[sent:])
    except socket.timeout as e:
        raise RpcTimeout("send timed out") from e
    except (BrokenPipeError, ConnectionResetError, OSError) as e:
        raise RpcClosed(f"send failed: {e}") from e


def _recv_exact(sock: socket.socket, n: int,
                deadline_at: Optional[float]) -> bytearray:
    buf = bytearray(n)
    view = memoryview(buf)
    got = 0
    try:
        while got < n:
            sock.settimeout(_remaining(deadline_at))
            k = sock.recv_into(view[got:], n - got)
            if k == 0:
                raise RpcClosed("peer closed mid-message")
            got += k
    except socket.timeout as e:
        raise RpcTimeout("recv timed out") from e
    except (ConnectionResetError, OSError) as e:
        raise RpcClosed(f"recv failed: {e}") from e
    return buf


def send_msg(sock: socket.socket, obj: Any, seq: int,
             deadline_s: Optional[float] = None,
             max_frame_bytes: int = MAX_FRAME_BYTES) -> None:
    """Frame + send one message; raises RpcTimeout/RpcClosed/FrameError."""
    deadline_at = (time.perf_counter() + deadline_s
                   if deadline_s is not None else None)
    parts = encode_message(obj)
    total = sum(len(p) for p in parts)
    if total > max_frame_bytes:
        raise FrameError(
            f"refusing to send {total}-byte frame (cap {max_frame_bytes})")
    lens = struct.pack(f"!{len(parts)}Q", *(len(p) for p in parts))
    crc = zlib.crc32(lens)
    for p in parts:
        crc = zlib.crc32(p, crc)
    header = _HDR.pack(MAGIC, seq, len(parts) - 1, crc)
    _sendall(sock, memoryview(header + lens), deadline_at)
    for p in parts:
        _sendall(sock, memoryview(p), deadline_at)


def recv_msg(sock: socket.socket,
             deadline_s: Optional[float] = None,
             max_frame_bytes: int = MAX_FRAME_BYTES) -> Tuple[int, Any]:
    """Receive + decode one message; returns ``(seq, obj)``."""
    deadline_at = (time.perf_counter() + deadline_s
                   if deadline_s is not None else None)
    hdr = _recv_exact(sock, _HDR.size, deadline_at)
    magic, seq, nbufs, want_crc = _HDR.unpack(bytes(hdr))
    if magic != MAGIC:
        raise FrameError(f"bad magic {magic!r}")
    if nbufs > 4096:
        raise FrameError(f"implausible buffer count {nbufs}")
    lens_raw = bytes(_recv_exact(sock, 8 * (nbufs + 1), deadline_at))
    lens = struct.unpack(f"!{nbufs + 1}Q", lens_raw)
    if sum(lens) > max_frame_bytes:
        raise FrameError(
            f"refusing {sum(lens)}-byte frame (cap {max_frame_bytes})")
    parts = [bytes(_recv_exact(sock, n, deadline_at)) for n in lens]
    crc = zlib.crc32(lens_raw)
    for p in parts:
        crc = zlib.crc32(p, crc)
    if crc != want_crc:
        # Verified BEFORE unpickling: corruption must fail at the frame
        # boundary, never as an arbitrary error inside pickle.loads.
        raise FrameError(
            f"crc mismatch (frame {want_crc:#010x}, computed {crc:#010x})")
    return seq, decode_message(parts)
