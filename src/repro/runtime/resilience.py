"""Failure handling primitives: bounded retries with backoff for transient
device/host errors, and a straggler monitor that flags slow steps against a
trailing median (the mitigation at scale: reshard away from the slow host via
the elastic planner, or preemptively restart it)."""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Deque, Optional, Tuple, TypeVar

import numpy as np

T = TypeVar("T")


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    max_attempts: int = 3
    backoff_s: float = 0.1
    backoff_mult: float = 2.0
    retryable: Tuple[type, ...] = (RuntimeError, OSError)
    # Wall-clock budget for the whole retry loop: once exceeded, the next
    # retryable failure re-raises even with attempts left, and every sleep
    # is capped to the remaining budget so the loop can never overrun it
    # asleep.  ``None`` = no deadline (the original behavior).
    deadline_s: Optional[float] = None
    # Decorrelated jitter (AWS-style): each sleep is drawn uniformly from
    # ``[backoff_s, prev_sleep * backoff_mult * (1 + jitter))`` so a fleet
    # of clients retrying against one recovering shard spreads out instead
    # of hammering it in lockstep.  ``jitter=0`` reproduces the exact
    # geometric sequence (tests pin it); ``seed`` makes the draw
    # deterministic for replayable chaos runs.
    jitter: float = 0.5
    seed: Optional[int] = None


def with_retries(fn: Callable[[], T], policy: RetryPolicy = RetryPolicy(),
                 on_retry: Optional[Callable[[int, Exception], None]] = None) -> T:
    delay = policy.backoff_s
    rng = None
    if policy.jitter > 0:
        rng = np.random.default_rng(policy.seed)
    t0 = time.perf_counter()
    for attempt in range(1, policy.max_attempts + 1):
        try:
            return fn()
        except policy.retryable as e:  # noqa: PERF203
            if attempt == policy.max_attempts:
                raise
            remaining = None
            if policy.deadline_s is not None:
                remaining = policy.deadline_s - (time.perf_counter() - t0)
                if remaining <= 0:
                    raise
            if on_retry:
                on_retry(attempt, e)
            sleep = delay
            if rng is not None:
                hi = delay * (1.0 + policy.jitter)
                sleep = float(rng.uniform(policy.backoff_s, hi)) \
                    if hi > policy.backoff_s else delay
            if remaining is not None:
                sleep = min(sleep, remaining)
            time.sleep(max(sleep, 0.0))
            delay = sleep * policy.backoff_mult
    raise AssertionError("unreachable")


class StragglerMonitor:
    """Flags steps slower than ``threshold`` x trailing median.

    At scale the same logic runs per-host on step barrier times; a flagged
    host is reported to the elastic controller.  Deterministic and
    unit-testable: feed it durations, read back flags.
    """

    def __init__(self, window: int = 32, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self._times: Deque[float] = deque(maxlen=window)
        self.flagged = 0

    def observe(self, duration_s: float) -> bool:
        med = self.median()
        self._times.append(duration_s)
        if med is None:
            return False
        slow = duration_s > self.threshold * med
        self.flagged += int(slow)
        return slow

    def median(self) -> Optional[float]:
        if len(self._times) < max(4, self.window // 4):
            return None
        s = sorted(self._times)
        return s[len(s) // 2]
