"""Process-stable hashing of query signatures and cache keys.

``hash()`` is salted per process (``PYTHONHASHSEED``), and ``repr()`` is
only *accidentally* stable: ``repr(np.float64(4.0))`` differs across numpy
major versions (``4.0`` vs ``np.float64(4.0)``), set/frozenset iteration
order follows the salted string hash, and interning can make two equal
strings print identically while hashing differently elsewhere.  Selection
keys (``PBDSEngine._select_key``) must be derived from query *content*
identically in every process — once shards are real processes, a
coordinator and a replica folding different hashes for the same query would
draw different selection randomness and diverge.

``canonical_repr`` is a deterministic serialization that equals ``repr``
for the plain-python values signatures are built from today (str, int,
float, bool, None, tuples) — so adopting it changed no existing key — while
normalizing the ways repr goes unstable: numpy scalars collapse to their
python value, sets/frozensets/dicts serialize in sorted canonical order,
and unknown objects are rejected loudly instead of falling back to a
default ``repr`` that embeds ``id()``.
"""
from __future__ import annotations

import zlib
from typing import Any


def canonical_repr(obj: Any) -> str:
    """Deterministic, process-stable repr for signature-shaped values.

    Supported: None, bool, int, float, str, bytes, tuple/list, dict,
    set/frozenset, and numpy scalars (normalized to their python value so
    ``Having(">", np.float64(4.0))`` and ``Having(">", 4.0)`` hash alike).
    Anything else raises ``TypeError`` — silently falling back to ``repr``
    would reintroduce exactly the instability this function removes.
    """
    if obj is None or obj is True or obj is False:
        return repr(obj)
    # numpy scalars (np.float64, np.int32, ...) before the exact-type
    # checks: bool/int/float subclasses with version-dependent reprs.
    item = getattr(obj, "item", None)
    if item is not None and getattr(obj, "shape", None) == ():
        return canonical_repr(obj.item())
    t = type(obj)
    if t is int or t is float or t is str or t is bytes:
        return repr(obj)
    if t is tuple:
        inner = ", ".join(canonical_repr(x) for x in obj)
        return f"({inner},)" if len(obj) == 1 else f"({inner})"
    if t is list:
        return "[" + ", ".join(canonical_repr(x) for x in obj) + "]"
    if t is dict:
        items = sorted((canonical_repr(k), canonical_repr(v)) for k, v in obj.items())
        return "{" + ", ".join(f"{k}: {v}" for k, v in items) + "}"
    if t is set or t is frozenset:
        tag = "set" if t is set else "frozenset"
        return tag + "{" + ", ".join(sorted(canonical_repr(x) for x in obj)) + "}"
    raise TypeError(
        f"canonical_repr: unsupported type {t.__name__!r} — extend the "
        f"canonical encoding rather than falling back to repr()")


def stable_hash32(obj: Any) -> int:
    """31-bit non-negative content hash, identical in every process.

    crc32 over :func:`canonical_repr` — matches the former
    ``zlib.crc32(repr(...))`` bit-for-bit on plain-python signatures, so
    switching the engine's ``_select_key`` over was behavior-preserving.
    """
    return zlib.crc32(canonical_repr(obj).encode()) & 0x7FFFFFFF
