"""Shared benchmark scaffolding: datasets at bench scale, timing, CSV out."""
from __future__ import annotations

import time
from typing import Callable, Dict, Tuple

import jax
import numpy as np

from repro.core.datasets import make_crimes, make_parking, make_stars, make_tpch
from repro.core.table import Database

ROWS = {"quick": 120_000, "full": 1_000_000}


def bench_databases(scale: str = "quick") -> Dict[str, Database]:
    n = ROWS[scale]
    return {
        "crimes": Database({"crimes": make_crimes(n)}),
        "tpch": make_tpch(n),
        "parking": Database({"parking": make_parking(n)}),
        "stars": Database({"stars": make_stars(n)}),
    }


def timeit(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
        best = min(best, time.perf_counter() - t0)
    return best, out


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
