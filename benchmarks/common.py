"""Shared benchmark scaffolding: datasets at bench scale, timing, CSV out."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Tuple

import jax
import numpy as np

from repro.core.datasets import make_crimes, make_parking, make_stars, make_tpch
from repro.core.table import Database

ROWS = {"quick": 120_000, "full": 1_000_000}


def bench_databases(scale: str = "quick") -> Dict[str, Database]:
    n = ROWS[scale]
    return {
        "crimes": Database({"crimes": make_crimes(n)}),
        "tpch": make_tpch(n),
        "parking": Database({"parking": make_parking(n)}),
        "stars": Database({"stars": make_stars(n)}),
    }


def block_until_ready(out: object) -> object:
    """Block on every device array reachable from ``out``.

    ``jax.block_until_ready`` only handles pytrees; benchmark functions also
    return plain dataclasses (QueryResult, SizeEstimate, ...) and containers
    of them, whose device work would otherwise be timed as zero.
    """
    seen = set()

    def _walk(x):
        if id(x) in seen:
            return
        seen.add(id(x))
        if dataclasses.is_dataclass(x) and not isinstance(x, type):
            for f in dataclasses.fields(x):
                _walk(getattr(x, f.name))
        elif isinstance(x, dict):
            for v in x.values():
                _walk(v)
        elif isinstance(x, (list, tuple)):
            for v in x:
                _walk(v)
        else:
            for leaf in jax.tree_util.tree_leaves(x):
                if hasattr(leaf, "block_until_ready"):
                    leaf.block_until_ready()

    _walk(out)
    return out


def timeit(fn: Callable, repeats: int = 3) -> Tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best, out


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
