"""Chaos-tolerance gates: differential at scale, recovery cost, health tax.

Three contracts from the chaos PR's acceptance criteria, all enforced at
quick scale (the CI chaos-smoke job):

  * **differential** — >= 100 seeded-random replay sequences (kill / stall /
    partition / flaky / heal interleaved with queries, query batches, appends
    and deletes) across 1-8 shards on the crimes schema AND all four workload
    templates (A-GH, A-JGH, AA-GH, AA-JGH) on the TPC-H join schema: every
    chaotic trace must be bit-identical to the fault-free replay of the same
    ops.  Chaos may change routing, never results.
  * **recovery** — bringing a killed shard back (probe + checkpoint adopt +
    delta replay + maintainer re-registration) must be >= 3x cheaper than
    cold re-capture: evicting the index and re-admitting the same sketches
    (selection + capture + registration on every shard), which is what the
    engine would pay without the recovery protocol.
  * **overhead** — fault-free serving with health tracking on (retry
    wrappers, straggler monitors, checkpoint bookkeeping) must cost <= 5%
    over ``health=False`` on the fused reuse path, measured interleaved so
    runner drift hits both sides equally.

``--json`` (via ``benchmarks.run``) writes ``BENCH_chaos.json``.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core import Aggregate, Database, Having, Query, ShardedEngine, execute
from repro.core.datasets import make_crimes, make_tpch
from repro.runtime.chaos import differential, random_ops, random_schedule

#: (shard_counts, seeds_per_count, ops_per_sequence) for the two schemas.
SEQ_PLAN = {
    "quick": {"crimes": (tuple(range(1, 9)), 10, 8), "tpch": ((2, 4, 6, 8), 6, 8)},
    "full": {"crimes": (tuple(range(1, 9)), 20, 12), "tpch": ((2, 4, 6, 8), 12, 10)},
}
MIN_SEQUENCES = 100
MIN_RECOVERY_SPEEDUP = 3.0
MAX_HEALTH_OVERHEAD = 1.05
RECOVERY_CYCLES = 3
OVERHEAD_REPEATS = 20


def _crimes_queries(db):
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = [dataclasses.replace(base, having=Having(">", float(np.quantile(sums, qt))))
          for qt in (0.5, 0.8)]
    byear = Query("crimes", ("year",), Aggregate("sum", "records"))
    qs.append(dataclasses.replace(byear, having=Having(
        ">", float(np.quantile(execute(byear, db).values, 0.6)))))
    return qs


def _crimes_rows(rng, n):
    t = make_crimes(n, seed=int(rng.integers(1 << 30)))
    return {a: np.asarray(t[a]) for a in t.schema}


def _tpch_templates(db):
    from repro.core import JoinSpec

    def thresh(q, qt):
        vals = execute(dataclasses.replace(q, having=None, outer_having=None),
                       db).values
        return float(np.quantile(vals, qt))

    agh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"))
    agh = dataclasses.replace(agh, having=Having(">", thresh(agh, 0.8)))
    ajgh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
                 join=JoinSpec("orders", "l_orderkey", "o_orderkey"))
    ajgh = dataclasses.replace(ajgh, having=Having(">", thresh(ajgh, 0.8)))
    aagh = Query("lineitem", ("l_partkey", "l_suppkey"),
                 Aggregate("sum", "l_quantity"), having=Having(">", 0.0),
                 outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None))
    aagh = dataclasses.replace(aagh, outer_having=Having(">", thresh(aagh, 0.8)))
    aajgh = Query("lineitem", ("l_partkey", "l_suppkey"), Aggregate("count", None),
                  join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
                  having=Having(">", 0.0),
                  outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None))
    aajgh = dataclasses.replace(
        aajgh, outer_having=Having(">", thresh(aajgh, 0.8)))
    return [agh, ajgh, aagh, aajgh]


def _run_differential(scale: str):
    plan = SEQ_PLAN[scale]
    total = identical = 0
    failures = []

    crimes_db = Database({"crimes": make_crimes(2500, seed=17)})
    crimes_qs = _crimes_queries(crimes_db)
    counts, seeds, n_ops = plan["crimes"]
    for n_shards in counts:
        for seed in range(seeds):
            ops = random_ops(seed * 31 + n_shards, n_ops, crimes_qs, _crimes_rows)
            events = random_schedule(seed * 97 + n_shards + 1000, n_ops, n_shards)
            ok, _, _ = differential(
                lambda n=n_shards: ShardedEngine(
                    crimes_db, "crimes", "district", n_shards=n, n_ranges=16,
                    theta=0.1, seed=0, min_selectivity_gain=2.0,
                    op_deadline_s=0.02),
                "crimes", ops, events)
            total += 1
            identical += ok
            if not ok:
                failures.append(("crimes", n_shards, seed))

    tpch_db = make_tpch(2000, seed=8)
    tpch_qs = _tpch_templates(tpch_db)

    def tpch_rows(rng, n):
        t = make_tpch(4 * n, seed=int(rng.integers(1 << 30)))["lineitem"]
        return {a: np.asarray(t[a])[:n] for a in t.schema}

    counts, seeds, n_ops = plan["tpch"]
    for n_shards in counts:
        for seed in range(seeds):
            ops = random_ops(seed * 53 + n_shards + 7, n_ops, tpch_qs, tpch_rows,
                             p_query=0.5, p_batch=0.2, p_append=0.2)
            events = random_schedule(seed * 41 + n_shards + 2000, n_ops, n_shards)
            ok, _, _ = differential(
                lambda n=n_shards: ShardedEngine(
                    tpch_db, "lineitem", "l_suppkey", n_shards=n, n_ranges=16,
                    theta=0.1, seed=0, min_selectivity_gain=1.0,
                    op_deadline_s=0.02),
                "lineitem", ops, events)
            total += 1
            identical += ok
            if not ok:
                failures.append(("tpch", n_shards, seed))
    return total, identical, failures


def _run_recovery(n_rows: int):
    """Recovery machinery (probe + checkpoint adopt + delta replay +
    maintainer re-registration, i.e. ``_catch_up_all`` post-heal) vs cold
    re-capture (evict the index and re-admit: selection + capture +
    registration on every shard — what the engine would have to do without
    the recovery protocol).

    Each kill/heal cycle runs on a fresh engine over the same table with the
    same append batch, so cycles are shape-identical: the first pays any
    one-time XLA compiles, min-of-N measures the steady-state cost — the
    same treatment the re-admission side gets from its min-of-N.
    """
    db = Database({"crimes": make_crimes(n_rows, seed=23)})
    qs = _crimes_queries(db)[:2]
    t = make_crimes(200, seed=77)
    batch = {a: np.asarray(t[a]) for a in t.schema}

    t_recover = float("inf")
    se = None
    for _ in range(RECOVERY_CYCLES):
        se = ShardedEngine(db, "crimes", "district", n_shards=4, n_ranges=32,
                           theta=0.1, seed=0, min_selectivity_gain=2.0)
        for q in qs:
            se.run(q)
            se.run(q)
        se.shards[1].inject("kill")
        se.run(qs[0])  # degraded serve: suspect
        se.run(qs[0])  # degraded serve: dead
        se.append_rows("crimes", batch)  # logged for the dead shard
        se.shards[1].heal()
        t0 = time.perf_counter()
        applied, down = se._catch_up_all()  # probe -> adopt -> replay -> re-reg
        t_recover = min(t_recover, time.perf_counter() - t0)
        assert not down and se.health[1] == "healthy"
        res, info = se.run(qs[0])
        assert not info.degraded
        assert res.canonical() == execute(qs[0], se.db).canonical()

    # Cold re-capture on the final engine (same table state, warm caches —
    # the generous baseline): evict every entry, re-admit from scratch.
    t_recapture = float("inf")
    for _ in range(RECOVERY_CYCLES):
        for e in list(se.engine.index.entries()):
            se.engine.index.remove(e)
            se._unregister(e.reg_id)
        t0 = time.perf_counter()
        created = 0
        for q in qs:
            _, info = se.run(q)
            created += info.created
        t_recapture = min(t_recapture, time.perf_counter() - t0)
        assert created >= 1  # the narrower query reuses the broad sketch
    return t_recover, t_recapture


def _run_overhead(n_rows: int):
    """Fault-free fused reuse latency, health tracking on vs off,
    interleaved best-of-N so load drift hits both engines equally."""
    db = Database({"crimes": make_crimes(n_rows, seed=29)})
    q = _crimes_queries(db)[0]
    engines = {
        "health": ShardedEngine(db, "crimes", "district", n_shards=4,
                                n_ranges=32, theta=0.1, seed=0,
                                min_selectivity_gain=2.0, health=True),
        "plain": ShardedEngine(db, "crimes", "district", n_shards=4,
                               n_ranges=32, theta=0.1, seed=0,
                               min_selectivity_gain=2.0, health=False),
    }
    for se in engines.values():
        se.run(q)
        se.run(q)  # warm the fused stack + compile
    best = {"health": float("inf"), "plain": float("inf")}
    for _ in range(OVERHEAD_REPEATS):
        for name, se in engines.items():
            t0 = time.perf_counter()
            _, info = se.run(q)
            best[name] = min(best[name], time.perf_counter() - t0)
            assert info.reused and not info.degraded
    return best["health"], best["plain"]


def run(scale: str = "quick", json_path: str | None = None):
    total, identical, failures = _run_differential(scale)
    n_rows = 60_000 if scale == "quick" else 200_000
    t_recover, t_recapture = _run_recovery(n_rows)
    t_health, t_plain = _run_overhead(n_rows)

    recovery_speedup = t_recapture / max(t_recover, 1e-9)
    overhead = t_health / max(t_plain, 1e-9)
    rows = [
        ("chaos_differential", total, identical, len(failures), "", ""),
        ("chaos_recovery", "", "", "", f"{t_recover*1e3:.3f}",
         f"{recovery_speedup:.2f}"),
        ("chaos_overhead", "", "", "", f"{t_health*1e3:.3f}",
         f"{overhead:.3f}"),
    ]
    emit(rows, ("bench", "sequences", "identical", "diverged", "ms", "ratio"))

    if json_path:  # write before the gates: the artifact lands either way
        with open(json_path, "w") as f:
            json.dump({
                "bench": "chaos", "scale": scale,
                "differential": {
                    "sequences": total, "identical": identical,
                    "min_sequences": MIN_SEQUENCES,
                    "failures": failures,
                },
                "recovery": {
                    "t_recover_ms": round(t_recover * 1e3, 3),
                    "t_recapture_ms": round(t_recapture * 1e3, 3),
                    "speedup": round(recovery_speedup, 2),
                    "min_speedup": MIN_RECOVERY_SPEEDUP,
                },
                "overhead": {
                    "t_health_ms": round(t_health * 1e3, 3),
                    "t_plain_ms": round(t_plain * 1e3, 3),
                    "ratio": round(overhead, 4),
                    "max_ratio": MAX_HEALTH_OVERHEAD,
                },
            }, f, indent=2)
        print(f"# wrote {json_path}")

    if scale == "quick":
        assert total >= MIN_SEQUENCES, (
            f"only {total} replay sequences (gate: >= {MIN_SEQUENCES})")
        assert identical == total, (
            f"{len(failures)} chaotic traces diverged from fault-free: "
            f"{failures[:5]}")
        assert recovery_speedup >= MIN_RECOVERY_SPEEDUP, (
            f"shard recovery ({t_recover*1e3:.2f}ms) is only "
            f"{recovery_speedup:.2f}x cheaper than cold re-capture "
            f"({t_recapture*1e3:.2f}ms); gate >= {MIN_RECOVERY_SPEEDUP}x")
        assert overhead <= MAX_HEALTH_OVERHEAD, (
            f"health tracking costs {overhead:.3f}x the untracked fused path "
            f"({t_health*1e3:.3f}ms vs {t_plain*1e3:.3f}ms); gate <= "
            f"{MAX_HEALTH_OVERHEAD}x")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", choices=["quick", "full"], default="quick")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    run(scale="quick" if args.quick else args.scale,
        json_path="BENCH_chaos.json" if args.json else None)
