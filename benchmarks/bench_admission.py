"""Batched vs sequential admission on the fig9 workload shape.

BENCH_fig9 showed the engine's cost is entirely on the miss path: per-miss
candidate selection plus capture+warmup dwarf reused execution.  This
benchmark drives a B-query cold miss batch (same inner-block signature,
thresholds spread over the selective quantiles — the fig9 repeated-template
regime) through ``PBDSEngine.run_batch`` and through sequential
``PBDSEngine.run``, and compares the per-query miss-path cost
(t_select + t_capture).  At quick scale the batched pipeline must be
>= ``MIN_SPEEDUP``x cheaper per query at B=16, and its results, index
contents and sketch bits must be bit-identical to sequential admission.

``--json`` (via ``benchmarks.run``) writes ``BENCH_admission.json``.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import bench_databases, emit
from repro.core import Aggregate, Having, Query, execute
from repro.core.engine import PBDSEngine

BATCH_SIZES = (4, 16)
MIN_SPEEDUP = 3.0  # enforced at quick scale, batch size 16

# One inner-block signature per dataset, thresholds drawn from the selective
# tail (quantile ranges chosen so the cost-based selector actually admits —
# the fig9 repeated-template regime where sketches pay off).
BASE_QUERIES = {
    "crimes": (Query("crimes", ("district", "year"), Aggregate("sum", "records")),
               (0.99, 0.85)),
    "stars": (Query("stars", ("field", "run"), Aggregate("sum", "mag_g")),
              (0.999, 0.99)),
}


def _miss_batch(db, base: Query, n: int, q_range):
    """n same-signature queries, descending thresholds (no subsumption)."""
    vals = execute(base, db).values
    taus = np.quantile(vals, np.linspace(q_range[0], q_range[1], n))
    return [dataclasses.replace(base, having=Having(">", float(t))) for t in taus]


def _index_bits(eng):
    return sorted(
        (repr(e.query.signature()), e.sketch.bits.tobytes(), e.sketch.size_rows)
        for e in eng.index.entries()
    )


def _engine(db):
    return PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=100, theta=0.05,
                      seed=9, min_selectivity_gain=0.95)


def run(scale: str = "quick", json_path: str | None = None):
    rows, results = [], []
    for ds, (base, q_range) in BASE_QUERIES.items():
        db = bench_databases(scale)[ds]
        for b in BATCH_SIZES:
            qs = _miss_batch(db, base, b, q_range)

            # Warm the process-wide XLA caches for BOTH paths on throwaway
            # engines: the bench compares steady-state per-miss cost, not
            # one-time kernel compilation (which either serving process pays
            # exactly once per shape class).
            warm = _engine(db)
            for q in qs:
                warm.run(q)
            _engine(db).run_batch(qs)

            eng_seq = _engine(db)
            t0 = time.perf_counter()
            seq = [eng_seq.run(q) for q in qs]
            t_seq_wall = time.perf_counter() - t0
            seq_miss = sum(i.t_select + i.t_capture for _, i in seq) / b

            eng_bat = _engine(db)
            t0 = time.perf_counter()
            bat = eng_bat.run_batch(qs)
            t_bat_wall = time.perf_counter() - t0
            bat_miss = sum(i.t_select + i.t_capture for _, i in bat) / b

            # Bit-identical admission: results, index contents, sketch bits.
            for (rs, _), (rb, _) in zip(seq, bat):
                assert rs.canonical() == rb.canonical(), "batched result diverged"
            assert _index_bits(eng_seq) == _index_bits(eng_bat), (
                "batched admission built a different index")

            n_created = sum(1 for _, i in bat if i.created)
            speedup = seq_miss / max(bat_miss, 1e-9)
            if scale == "quick" and b == max(BATCH_SIZES):
                assert speedup >= MIN_SPEEDUP, (
                    f"{ds}: batched admission only {speedup:.2f}x cheaper per "
                    f"query at B={b} (need >= {MIN_SPEEDUP}x)")
            results.append(dict(
                dataset=ds,
                batch_size=b,
                n_created=n_created,
                seq_miss_per_query_s=round(seq_miss, 6),
                bat_miss_per_query_s=round(bat_miss, 6),
                seq_wall_s=round(t_seq_wall, 4),
                bat_wall_s=round(t_bat_wall, 4),
                speedup=round(speedup, 2),
                wall_speedup=round(t_seq_wall / max(t_bat_wall, 1e-9), 2),
            ))
            rows.append(("admission", ds, b, n_created,
                         f"{seq_miss*1e3:.2f}", f"{bat_miss*1e3:.2f}",
                         f"{speedup:.2f}",
                         f"{t_seq_wall:.3f}", f"{t_bat_wall:.3f}"))

    emit(rows, ("bench", "dataset", "batch", "created", "seq_miss_ms_per_q",
                "bat_miss_ms_per_q", "speedup", "seq_wall_s", "bat_wall_s"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"bench": "admission", "scale": scale,
                       "min_speedup_required": MIN_SPEEDUP,
                       "results": results}, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["quick", "full"], default="quick")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    run("quick" if args.quick else args.scale,
        json_path="BENCH_admission.json" if args.json else None)
