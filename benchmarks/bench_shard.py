"""Fragment-sharded serving: routed vs single-node reused-query latency.

For shard counts 1/2/4/8 this benchmark builds a ``ShardedEngine`` over the
crimes table, captures a selective sketch once, and times the *reused* (index
hit) path — the serving steady state the sharding exists for.  Reported per
shard count:

  * ``t_routed_ms``  — coordinator wall time of one routed execution
    (host-emulated shards run sequentially in-process, so this is the
    *sum* of per-shard work + merge);
  * ``t_critical_ms`` — the slowest contacted shard + merge, i.e. the
    emulated shard-parallel latency a real deployment would see;
  * ``contacted`` / ``skipped`` — fragment routing effectiveness: a
    selective sketch touches only the shards owning its fragments.

Contracts enforced at quick scale (the CI smoke job runs 2 shards):

  * routed latency at 1 shard <= 1.5x the single-node reuse latency (the
    routing layer may not tax the degenerate case), and
  * skipped > 0 at >= 2 shards for the selective sketch, and
  * the emulated parallel latency improves from 1 shard to 4+ shards.

``--json`` (via ``benchmarks.run``) writes ``BENCH_shard.json``.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import ROWS, emit
from repro.core import Aggregate, Database, Having, Query, ShardedEngine, execute
from repro.core.datasets import make_crimes
from repro.core.engine import PBDSEngine

SHARD_COUNTS = (1, 2, 4, 8)
MAX_SINGLE_NODE_RATIO = 1.5
REPEATS = 5


def _selective_query(db):
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    tau = float(np.quantile(execute(base, db).values, 0.9))
    return dataclasses.replace(base, having=Having(">", tau))


def _time_reuse(run_fn, repeats=REPEATS, route_of=None):
    """Best-of-N wall time (+ best critical path when ``route_of`` is the
    engine — routing jitter is per-repeat, so both take the min)."""
    best = float("inf")
    best_critical = float("inf")
    info = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, info = run_fn()
        best = min(best, time.perf_counter() - t0)
        if route_of is not None and route_of.last_route is not None:
            best_critical = min(best_critical, route_of.last_route.t_critical_s)
    return best, best_critical, info


def run(scale: str = "quick", json_path: str | None = None,
        shard_counts=SHARD_COUNTS):
    n = ROWS[scale]
    db = Database({"crimes": make_crimes(n, seed=17)})
    q = _selective_query(db)

    # Single-node baseline: same strategy, clustered fact table, warm reuse.
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.05, seed=0,
                     cluster_tables=True, min_selectivity_gain=2.0)
    _, cold = eng.run(q)
    assert cold.created, "baseline must capture a sketch"
    t_single, _, info_s = _time_reuse(lambda: eng.run(q))
    assert info_s.reused

    rows, results = [], []
    critical_by_shards = {}
    for s in shard_counts:
        se = ShardedEngine(db, "crimes", "district", n_shards=s, n_ranges=50,
                           theta=0.05, seed=0, min_selectivity_gain=2.0)
        _, cold = se.run(q)
        assert cold.created, "sharded engine must capture a sketch"
        t_routed, t_critical, info = _time_reuse(lambda: se.run(q), route_of=se)
        assert info.reused and info.shards_contacted is not None
        critical_by_shards[s] = t_critical
        if scale == "quick":
            if s == 1:
                assert t_routed <= MAX_SINGLE_NODE_RATIO * t_single, (
                    f"routing tax at 1 shard: {t_routed*1e3:.2f}ms routed vs "
                    f"{t_single*1e3:.2f}ms single-node "
                    f"(allowed {MAX_SINGLE_NODE_RATIO}x)")
            if s >= 2:
                assert info.shards_skipped > 0, (
                    f"selective sketch skipped no shards at {s} shards")
        results.append(dict(
            n_shards=s,
            t_routed_ms=round(t_routed * 1e3, 3),
            t_critical_ms=round(t_critical * 1e3, 3),
            t_single_node_ms=round(t_single * 1e3, 3),
            contacted=info.shards_contacted,
            skipped=info.shards_skipped,
            routed_vs_single=round(t_routed / max(t_single, 1e-9), 3),
            parallel_speedup=round(
                critical_by_shards[shard_counts[0]] / max(t_critical, 1e-9), 2),
        ))
        rows.append(("shard", s, f"{t_routed*1e3:.3f}", f"{t_critical*1e3:.3f}",
                     f"{t_single*1e3:.3f}", info.shards_contacted,
                     info.shards_skipped))
    if scale == "quick" and 4 in critical_by_shards:
        # 1.2x tolerance: the contract is "no worse, trending better" — CI
        # runners share cores, so a hard <1.0 bound would flake on noise.
        assert (critical_by_shards[4]
                <= critical_by_shards[shard_counts[0]] * 1.2), (
            "shard-parallel critical path did not improve at 4 shards: "
            f"{critical_by_shards}")

    emit(rows, ("bench", "n_shards", "routed_ms", "critical_ms",
                "single_node_ms", "contacted", "skipped"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"bench": "shard", "scale": scale,
                       "max_single_node_ratio": MAX_SINGLE_NODE_RATIO,
                       "results": results}, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", choices=["quick", "full"], default="quick")
    ap.add_argument("--shards", type=int, nargs="*", default=None,
                    help="shard counts to run (default 1 2 4 8)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    scale = "quick" if args.quick else args.scale
    run(scale=scale,
        json_path="BENCH_shard.json" if args.json else None,
        shard_counts=tuple(args.shards) if args.shards else SHARD_COUNTS)
