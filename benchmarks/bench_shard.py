"""Fragment-sharded serving: fused SPMD vs host-loop vs single-node latency.

For shard counts 1/2/4/8 this benchmark builds a ``ShardedEngine`` over the
crimes table, captures a selective sketch once, and times the *reused* (index
hit) path — the serving steady state the sharding exists for — through BOTH
serving paths:

  * ``t_fused_ms``  — the stacked one-launch SPMD path (default): all
    contacted shards' per-group partials come out of one XLA program;
  * ``t_loop_ms``   — the per-shard host loop (one ``partial()`` launch per
    contacted shard, merged on the coordinator);
  * ``t_critical_ms`` — the emulated shard-parallel latency (fused: launch +
    merge; host loop: slowest contacted shard + merge);
  * ``t_batch_per_query_ms`` — per-query wall time of an 8-query warm hit
    batch through ``run_batch`` (B×S partials in one program);
  * ``contacted`` / ``skipped`` — fragment routing effectiveness.

Contracts enforced at quick scale (the CI smoke job runs 1/2/4 shards):

  * fused routed latency at 1 shard <= 1.5x the single-node reuse latency
    (the routing layer may not tax the degenerate case),
  * skipped > 0 at >= 2 shards for the selective sketch,
  * **fused routed <= 1.0x single-node wall time at 4 shards** (the fused
    launch must beat the Python shard loop that used to cost 1.13x), and
  * fused and host-loop results are bit-identical.

``--json`` (via ``benchmarks.run``) writes ``BENCH_shard.json``.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import ROWS, emit
from repro.core import Aggregate, Database, Having, Query, ShardedEngine, execute
from repro.core.datasets import make_crimes
from repro.core.engine import PBDSEngine

SHARD_COUNTS = (1, 2, 4, 8)
MAX_SINGLE_NODE_RATIO = 1.5
FUSED_MAX_RATIO_AT_4 = 1.0
BATCH = 8
REPEATS = 7


def _selective_query(db):
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    tau = float(np.quantile(execute(base, db).values, 0.9))
    return dataclasses.replace(base, having=Having(">", tau))


def _time_reuse(run_fn, repeats=REPEATS, route_of=None):
    """Best-of-N wall time (+ best critical path when ``route_of`` is the
    engine — routing jitter is per-repeat, so both take the min)."""
    best = float("inf")
    best_critical = float("inf")
    info = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        _, info = run_fn()
        best = min(best, time.perf_counter() - t0)
        if route_of is not None and route_of.last_route is not None:
            best_critical = min(best_critical, route_of.last_route.t_critical_s)
    return best, best_critical, info


def run(scale: str = "quick", json_path: str | None = None,
        shard_counts=SHARD_COUNTS):
    n = ROWS[scale]
    db = Database({"crimes": make_crimes(n, seed=17)})
    q = _selective_query(db)

    # Single-node baseline: same strategy, clustered fact table, warm reuse.
    eng = PBDSEngine(db, strategy="CB-OPT-GB", n_ranges=50, theta=0.05, seed=0,
                     cluster_tables=True, min_selectivity_gain=2.0)
    _, cold = eng.run(q)
    assert cold.created, "baseline must capture a sketch"
    t_single, _, info_s = _time_reuse(lambda: eng.run(q))
    assert info_s.reused

    rows, results = [], []
    fused_critical, loop_critical = {}, {}
    for s in shard_counts:
        se = ShardedEngine(db, "crimes", "district", n_shards=s, n_ranges=50,
                           theta=0.05, seed=0, min_selectivity_gain=2.0)
        _, cold = se.run(q)
        assert cold.created, "sharded engine must capture a sketch"

        se.fused = False
        res_loop, _ = se.run(q)  # warm the host-loop path
        t_loop, crit_loop, info_l = _time_reuse(lambda: se.run(q), route_of=se)
        assert info_l.reused and not se.last_route.fused
        loop_critical[s] = crit_loop

        se.fused = True
        res_fused, _ = se.run(q)  # warm: builds the stack + compiles
        t_fused, crit_fused, info = _time_reuse(lambda: se.run(q), route_of=se)
        assert info.reused and se.last_route.fused
        assert np.array_equal(res_fused.values, res_loop.values), (
            "fused and host-loop results diverged")
        fused_critical[s] = crit_fused

        batch = [q] * BATCH
        se.run_batch(batch)  # warm the batched hit path
        t_batch = float("inf")
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            se.run_batch(batch)
            t_batch = min(t_batch, (time.perf_counter() - t0) / BATCH)

        if scale == "quick":
            if s == 1:
                assert t_fused <= MAX_SINGLE_NODE_RATIO * t_single, (
                    f"routing tax at 1 shard: {t_fused*1e3:.2f}ms fused vs "
                    f"{t_single*1e3:.2f}ms single-node "
                    f"(allowed {MAX_SINGLE_NODE_RATIO}x)")
            if s >= 2:
                assert info.shards_skipped > 0, (
                    f"selective sketch skipped no shards at {s} shards")
            if s == 4:
                # Gate against an *adjacent* single-node re-measurement:
                # runner load drifts over the benchmark's lifetime, and a
                # baseline taken 30s earlier would make a 1.0x bound flake.
                t_single_adj, _, _ = _time_reuse(lambda: eng.run(q))
                t_ref = max(t_single, t_single_adj)
                assert t_fused <= FUSED_MAX_RATIO_AT_4 * t_ref, (
                    f"fused routed serving at 4 shards is "
                    f"{t_fused / t_ref:.2f}x single-node "
                    f"(gate: <= {FUSED_MAX_RATIO_AT_4}x)")
        results.append(dict(
            n_shards=s,
            t_fused_ms=round(t_fused * 1e3, 3),
            t_loop_ms=round(t_loop * 1e3, 3),
            t_critical_ms=round(crit_fused * 1e3, 3),
            t_loop_critical_ms=round(crit_loop * 1e3, 3),
            t_batch_per_query_ms=round(t_batch * 1e3, 3),
            t_single_node_ms=round(t_single * 1e3, 3),
            contacted=info.shards_contacted,
            skipped=info.shards_skipped,
            routed_vs_single=round(t_fused / max(t_single, 1e-9), 3),
            loop_vs_single=round(t_loop / max(t_single, 1e-9), 3),
            parallel_speedup=round(
                fused_critical[shard_counts[0]] / max(crit_fused, 1e-9), 2),
        ))
        rows.append(("shard", s, f"{t_fused*1e3:.3f}", f"{t_loop*1e3:.3f}",
                     f"{t_batch*1e3:.3f}", f"{t_single*1e3:.3f}",
                     info.shards_contacted, info.shards_skipped))
    # The old relative trend gate (critical path no worse at 4 shards, 1.2x
    # tolerance) is superseded by the absolute fused <= 1.0x single-node gate
    # above — a strictly stronger statement, and one that doesn't flake on a
    # selective sketch that routes to a single shard (contacted=1 makes
    # "parallel speedup" pure timer noise).  Criticals stay reported.

    emit(rows, ("bench", "n_shards", "fused_ms", "loop_ms", "batch_per_q_ms",
                "single_node_ms", "contacted", "skipped"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"bench": "shard", "scale": scale,
                       "max_single_node_ratio": MAX_SINGLE_NODE_RATIO,
                       "fused_max_ratio_at_4": FUSED_MAX_RATIO_AT_4,
                       "results": results}, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", choices=["quick", "full"], default="quick")
    ap.add_argument("--shards", type=int, nargs="*", default=None,
                    help="shard counts to run (default 1 2 4 8)")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    scale = "quick" if args.quick else args.scale
    run(scale=scale,
        json_path="BENCH_shard.json" if args.json else None,
        shard_counts=tuple(args.shards) if args.shards else SHARD_COUNTS)
