"""Fig. 4: relative sketch-size error vs number of bootstrap resamples over
TPC-H.  The paper's knee is at ~50 resamples; we sweep the same axis."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import bench_databases, emit
from repro.aqp.sampling import stratified_reservoir_sample
from repro.aqp.size_estimation import EstimationConfig, estimate_size
from repro.core import capture_sketch, equi_depth_ranges
from repro.core.workload import TPCH_SPEC, generate_workload


def run(scale: str = "quick", n_queries: int = 12, n_ranges: int = 100):
    db = bench_databases(scale)["tpch"]
    queries = generate_workload(TPCH_SPEC, db, n_queries, seed=4)
    rows = []
    key = jax.random.PRNGKey(4)
    for B in (1, 5, 10, 25, 50, 100):
        errs = []
        for i, q in enumerate(queries):
            kq = jax.random.fold_in(key, i)
            samples = stratified_reservoir_sample(kq, db[q.table], q.groupby, 0.05)
            attr = q.groupby[0]
            ranges = equi_depth_ranges(db[q.table], attr, n_ranges)
            cfg = EstimationConfig(n_resamples=B, use_bootstrap=B > 1)
            est = estimate_size(kq, q, db, ranges, samples, cfg)
            actual = capture_sketch(q, db, ranges).size_rows
            if actual > 0:
                errs.append(abs(est.est_rows - actual) / actual)
        rows.append(("fig4", B, f"{np.mean(errs):.4f}", f"{np.median(errs):.4f}", len(errs)))
    return emit(rows, ("bench", "n_resamples", "mean_rse", "median_rse", "n"))


if __name__ == "__main__":
    run()
