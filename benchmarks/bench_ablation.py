"""Beyond-paper ablations: sample rate theta x partition count n_ranges, and
single- vs composite-attribute sketches (multisketch extension)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_databases, emit
from repro.aqp.sampling import SampleCache
from repro.core import capture_sketch, equi_depth_ranges, select_attribute
from repro.core.multisketch import capture_composite, select_composite_gb
from repro.core.workload import CRIMES_SPEC, generate_workload


def run(scale: str = "quick", n_queries: int = 6):
    db = bench_databases(scale)["crimes"]
    queries = generate_workload(CRIMES_SPEC, db, n_queries, seed=5)
    key = jax.random.PRNGKey(5)
    rows = []

    # --- theta sweep: estimation quality vs sampling cost ------------------
    for theta in (0.01, 0.02, 0.05, 0.1, 0.2):
        errs, times = [], []
        import time

        for i, q in enumerate(queries):
            kq = jax.random.fold_in(key, i)
            t0 = time.perf_counter()
            sel = select_attribute("CB-OPT-GB", kq, q, db, 100, SampleCache(), theta=theta)
            times.append(time.perf_counter() - t0)
            if sel.attr is None:
                continue
            est = sel.estimates[sel.attr]
            actual = capture_sketch(q, db, equi_depth_ranges(db["crimes"], sel.attr, 100)).size_rows
            if actual:
                errs.append(abs(est.est_rows - actual) / actual)
        rows.append(("ablate-theta", theta, f"{np.mean(errs):.4f}", f"{np.mean(times)*1e3:.1f}"))

    # --- n_ranges sweep: sketch granularity vs selectivity ------------------
    for nr in (25, 100, 400, 1000):
        sels = []
        for q in queries:
            sel = select_attribute("OPT", key, q, db, nr)
            if sel.attr is None:
                continue
            sels.append(capture_sketch(q, db, equi_depth_ranges(db["crimes"], sel.attr, nr)).selectivity)
        rows.append(("ablate-nranges", nr, f"{np.mean(sels):.4f}", "-"))

    # --- composite vs single sketches (beyond-paper) -------------------------
    single, comp = [], []
    for i, q in enumerate(queries):
        if len(q.groupby) < 2:
            continue
        kq = jax.random.fold_in(key, 100 + i)
        s1 = select_attribute("CB-OPT-GB", kq, q, db, 100, SampleCache(), theta=0.1)
        if s1.attr is None:
            continue
        single.append(capture_sketch(q, db, equi_depth_ranges(db["crimes"], s1.attr, 100)).selectivity)
        best, cr, _ = select_composite_gb(kq, q, db, 100, theta=0.1)
        comp.append(capture_composite(q, db, cr).selectivity)
    if single:
        rows.append(("ablate-composite", "single-CB-OPT-GB", f"{np.mean(single):.4f}", len(single)))
        rows.append(("ablate-composite", "composite-CB-OPT-GB2", f"{np.mean(comp):.4f}", len(comp)))
    return emit(rows, ("bench", "param", "value", "extra"))


if __name__ == "__main__":
    run()
