"""Maintain-vs-recapture latency across append batch sizes.

For a captured sketch on the crimes table, each appended batch can be folded
into the sketch either by the incremental maintainer (bucketize/encode the
batch, update counters, re-OR touched fragments) or by a from-scratch
re-capture (full provenance recomputation).  This benchmark times both across
batch sizes and enforces the maintenance subsystem's two contracts at quick
scale:

  * maintained append handling is >= 5x faster than re-capture, and
  * the delta path does zero full-table re-bucketization (catalog miss
    counters stay frozen while the *_delta counters advance).

``--json`` (via ``benchmarks.run``) writes ``BENCH_maintenance.json`` so the
maintain/recapture trajectory is tracked across PRs.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import ROWS, emit, timeit
from repro.core import (
    Aggregate,
    Catalog,
    Database,
    Having,
    Query,
    build_maintainer,
    capture_sketch,
    equi_depth_ranges,
    execute,
)
from repro.core.datasets import make_crimes

BATCH_SIZES = {"quick": (1_000, 5_000, 20_000), "full": (10_000, 50_000, 200_000)}
MIN_SPEEDUP = 5.0


def _batch(n, seed):
    t = make_crimes(n, seed=seed)
    return {a: np.asarray(t[a]) for a in t.schema}


def run(scale: str = "quick", json_path: str | None = None):
    n = ROWS[scale]
    table = make_crimes(n, seed=17)
    db = Database({"crimes": table})
    q = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    tau = float(np.quantile(execute(q, db).values, 0.8))
    q = dataclasses.replace(q, having=Having(">", tau))
    ranges = equi_depth_ranges(table, "district", 25)

    cat = Catalog()
    capture_sketch(q, db, ranges, catalog=cat)  # warm: capture-time state
    maintainer = build_maintainer(q, db, ranges, cat)

    rows, results = [], []
    for i, batch_size in enumerate(BATCH_SIZES[scale]):
        full_misses_before = {
            k: cat.stats.get(k, 0)
            for k in ("bucketize", "encode_groups", "fragment_sizes")
        }
        # Three successive appends of the same batch shape; best-of timing so
        # the one-off XLA compile of the batch-shaped bucketize does not count
        # against the steady-state delta cost (the re-capture side gets the
        # same best-of-3 treatment from ``timeit``).
        t_maintain = float("inf")
        sk_m = None
        for r in range(3):
            batch = _batch(batch_size, seed=100 + 10 * i + r)
            t2 = table.append(batch)
            db2 = db.with_table(t2)
            t0 = time.perf_counter()
            maintainer.apply(t2, db2)
            sk_m = maintainer.to_sketch(t2, cat)
            t_maintain = min(t_maintain, time.perf_counter() - t0)
            table, db = t2, db2  # chain: versions keep advancing
        full_misses_after = {
            k: cat.stats.get(k, 0)
            for k in ("bucketize", "encode_groups", "fragment_sizes")
        }
        # Zero full-table re-bucketization / re-encoding on the delta path.
        assert full_misses_after == full_misses_before, (
            f"delta path did full-table work: {full_misses_before} -> {full_misses_after}")
        assert cat.stats.get("bucketize_delta", 0) > 0

        # Re-capture oracle: a fresh catalog per repeat so nothing incremental
        # (cached bucketizations, encodings) subsidizes the re-capture cost.
        t_recapture, sk_r = timeit(
            lambda: capture_sketch(q, db, ranges, catalog=Catalog()))
        np.testing.assert_array_equal(sk_m.bits, sk_r.bits)

        speedup = t_recapture / max(t_maintain, 1e-9)
        if scale == "quick":
            assert speedup >= MIN_SPEEDUP, (
                f"maintained append only {speedup:.1f}x faster than re-capture "
                f"at batch={batch_size} (need >= {MIN_SPEEDUP}x)")
        results.append(dict(
            batch_size=batch_size,
            t_maintain_s=round(t_maintain, 6),
            t_recapture_s=round(t_recapture, 6),
            speedup=round(speedup, 2),
            bucketize_delta=cat.stats.get("bucketize_delta", 0),
            bucketize_full=cat.stats.get("bucketize", 0),
        ))
        rows.append(("maintenance", batch_size, f"{t_maintain*1e3:.3f}",
                     f"{t_recapture*1e3:.3f}", f"{speedup:.2f}"))

    emit(rows, ("bench", "append_batch", "maintain_ms", "recapture_ms", "speedup"))
    if json_path:
        with open(json_path, "w") as f:
            json.dump({"bench": "maintenance", "scale": scale,
                       "min_speedup_required": MIN_SPEEDUP,
                       "results": results}, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    run()
