"""RPC-transport gates: cross-backend differential at scale, transport tax,
process-kill recovery cost.

Three contracts from the RPC PR's acceptance criteria, enforced at quick
scale (the CI multiprocess-smoke job runs the pytest smoke; this bench is
the full-size version):

  * **differential** — >= 100 seeded-random replay sequences (kill / stall /
    partition / flaky / heal interleaved with queries, query batches, appends
    and deletes) across 1-8 shards on the crimes schema AND all four workload
    templates (A-GH, A-JGH, AA-GH, AA-JGH) on the TPC-H join schema, with the
    chaotic engine on the **real subprocess backend** (every shard a separate
    OS process; kill is SIGKILL, partition a dropped socket) and the
    fault-free reference running **in-process fused**.  Every chaotic
    multi-process trace must be bit-identical to the single-process replay.
  * **overhead** — warm reuse over the subprocess backend must cost <= 1.3x
    the in-process routed warm hit, measured interleaved so runner drift hits
    both sides equally.  (The client caches state tokens and sketch bits off
    RPC response metadata, so a warm hit pays no per-query round trips — this
    gate pins that.)
  * **recovery** — SIGKILL a shard server, heal, and time the coordinator's
    recovery (respawn + checkpoint ship + delta replay + maintainer
    re-registration over RPC) against cold re-capture (rebuild the shard
    from the current table — a killed process has no state either way —
    then evict the index and re-admit every sketch: selection + capture +
    registration on all shards).  Recovery must be >= 3x cheaper.

``--json`` (via ``benchmarks.run``) writes ``BENCH_rpc.json``.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core import Aggregate, Database, Having, Query, ShardedEngine, execute
from repro.core.datasets import make_crimes, make_tpch
from repro.runtime.chaos import differential, random_ops, random_schedule

#: (shard_counts, seeds_per_count, ops_per_sequence) for the two schemas.
SEQ_PLAN = {
    "quick": {"crimes": (tuple(range(1, 9)), 10, 8), "tpch": ((2, 4, 6, 8), 6, 8)},
    "full": {"crimes": (tuple(range(1, 9)), 16, 10), "tpch": ((2, 4, 6, 8), 10, 8)},
}
MIN_SEQUENCES = 100
MAX_TRANSPORT_OVERHEAD = 1.3
MIN_RECOVERY_SPEEDUP = 3.0
RECOVERY_CYCLES = 3
OVERHEAD_REPEATS = 20
#: Engine op deadline on the subprocess backend: real RPCs have real latency,
#: so the deadline sits well above a round trip but low enough that a stalled
#: or killed server is detected within a replayed sequence.
RPC_OP_DEADLINE_S = 0.5


def _crimes_queries(db):
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    sums = execute(base, db).values
    qs = [dataclasses.replace(base, having=Having(">", float(np.quantile(sums, qt))))
          for qt in (0.5, 0.8)]
    byear = Query("crimes", ("year",), Aggregate("sum", "records"))
    qs.append(dataclasses.replace(byear, having=Having(
        ">", float(np.quantile(execute(byear, db).values, 0.6)))))
    return qs


def _crimes_rows(rng, n):
    t = make_crimes(n, seed=int(rng.integers(1 << 30)))
    return {a: np.asarray(t[a]) for a in t.schema}


def _tpch_templates(db):
    from repro.core import JoinSpec

    def thresh(q, qt):
        vals = execute(dataclasses.replace(q, having=None, outer_having=None),
                       db).values
        return float(np.quantile(vals, qt))

    agh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"))
    agh = dataclasses.replace(agh, having=Having(">", thresh(agh, 0.8)))
    ajgh = Query("lineitem", ("l_suppkey",), Aggregate("sum", "l_quantity"),
                 join=JoinSpec("orders", "l_orderkey", "o_orderkey"))
    ajgh = dataclasses.replace(ajgh, having=Having(">", thresh(ajgh, 0.8)))
    aagh = Query("lineitem", ("l_partkey", "l_suppkey"),
                 Aggregate("sum", "l_quantity"), having=Having(">", 0.0),
                 outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None))
    aagh = dataclasses.replace(aagh, outer_having=Having(">", thresh(aagh, 0.8)))
    aajgh = Query("lineitem", ("l_partkey", "l_suppkey"), Aggregate("count", None),
                  join=JoinSpec("orders", "l_orderkey", "o_orderkey"),
                  having=Having(">", 0.0),
                  outer_groupby=("l_suppkey",), outer_agg=Aggregate("sum", None))
    aajgh = dataclasses.replace(
        aajgh, outer_having=Having(">", thresh(aajgh, 0.8)))
    return [agh, ajgh, aagh, aajgh]


def _subprocess_engine(db, table, attr, n_shards, **kw):
    return ShardedEngine(db, table, attr, n_shards=n_shards, n_ranges=16,
                         theta=0.1, seed=0, transport="subprocess",
                         op_deadline_s=RPC_OP_DEADLINE_S, **kw)


def _loopback_engine(db, table, attr, n_shards, **kw):
    return ShardedEngine(db, table, attr, n_shards=n_shards, n_ranges=16,
                         theta=0.1, seed=0, transport="loopback", **kw)


def _run_differential(scale: str):
    plan = SEQ_PLAN[scale]
    total = identical = 0
    failures = []

    crimes_db = Database({"crimes": make_crimes(2500, seed=17)})
    crimes_qs = _crimes_queries(crimes_db)
    counts, seeds, n_ops = plan["crimes"]
    for n_shards in counts:
        for seed in range(seeds):
            ops = random_ops(seed * 31 + n_shards, n_ops, crimes_qs, _crimes_rows)
            events = random_schedule(seed * 97 + n_shards + 1000, n_ops, n_shards)
            ok, _, _ = differential(
                lambda n=n_shards: _subprocess_engine(
                    crimes_db, "crimes", "district", n,
                    min_selectivity_gain=2.0),
                "crimes", ops, events,
                make_clean=lambda n=n_shards: _loopback_engine(
                    crimes_db, "crimes", "district", n,
                    min_selectivity_gain=2.0))
            total += 1
            identical += ok
            if not ok:
                failures.append(("crimes", n_shards, seed))
        print(f"#   crimes n_shards={n_shards}: {total} sequences, "
              f"{total - identical} diverged", flush=True)

    tpch_db = make_tpch(2000, seed=8)
    tpch_qs = _tpch_templates(tpch_db)

    def tpch_rows(rng, n):
        t = make_tpch(4 * n, seed=int(rng.integers(1 << 30)))["lineitem"]
        return {a: np.asarray(t[a])[:n] for a in t.schema}

    counts, seeds, n_ops = plan["tpch"]
    for n_shards in counts:
        for seed in range(seeds):
            ops = random_ops(seed * 53 + n_shards + 7, n_ops, tpch_qs, tpch_rows,
                             p_query=0.5, p_batch=0.2, p_append=0.2)
            events = random_schedule(seed * 41 + n_shards + 2000, n_ops, n_shards)
            ok, _, _ = differential(
                lambda n=n_shards: _subprocess_engine(
                    tpch_db, "lineitem", "l_suppkey", n,
                    min_selectivity_gain=1.0),
                "lineitem", ops, events,
                make_clean=lambda n=n_shards: _loopback_engine(
                    tpch_db, "lineitem", "l_suppkey", n,
                    min_selectivity_gain=1.0))
            total += 1
            identical += ok
            if not ok:
                failures.append(("tpch", n_shards, seed))
        print(f"#   tpch n_shards={n_shards}: {total} sequences, "
              f"{total - identical} diverged", flush=True)
    return total, identical, failures


def _run_overhead(n_rows: int):
    """Fault-free warm reuse latency, subprocess vs in-process routed,
    interleaved best-of-N so load drift hits both engines equally."""
    db = Database({"crimes": make_crimes(n_rows, seed=29)})
    q = _crimes_queries(db)[0]
    engines = {
        "subprocess": _subprocess_engine(db, "crimes", "district", 4,
                                         min_selectivity_gain=2.0),
        "loopback": _loopback_engine(db, "crimes", "district", 4,
                                     min_selectivity_gain=2.0),
    }
    try:
        for se in engines.values():
            se.run(q)
            se.run(q)  # warm the fused stack + compile (+ bits/token caches)
        best = {"subprocess": float("inf"), "loopback": float("inf")}
        for _ in range(OVERHEAD_REPEATS):
            for name, se in engines.items():
                t0 = time.perf_counter()
                _, info = se.run(q)
                best[name] = min(best[name], time.perf_counter() - t0)
                assert info.reused and not info.degraded
    finally:
        for se in engines.values():
            se.shutdown()
    return best["subprocess"], best["loopback"]


def _recovery_queries(db):
    """A sketch-rich workload: eight distinct group-by templates, each
    admitting its own sketch — the regime the recovery protocol exists for
    (re-registration replays maintainers; re-capture re-scans per sketch)."""
    def q_for(gb, qt=0.7):
        q = Query("crimes", gb, Aggregate("sum", "records"))
        vals = execute(q, db).values
        return dataclasses.replace(
            q, having=Having(">", float(np.quantile(vals, qt))))

    return [q_for(("district", "year")), q_for(("year",)),
            q_for(("district", "month")), q_for(("ward", "year")),
            q_for(("community",)), q_for(("beat",)),
            q_for(("month", "year")), q_for(("zipcode",))]


def _run_recovery(n_rows: int):
    """Process-kill recovery vs cold re-capture, both paths starting from
    the same state: shard 1 SIGKILLed, healthy shards current, a delta
    batch logged while it was down, and a fresh (compile-cold) server
    process just healed in from the pool.

      * recovery — what ``_catch_up_all`` does: ship the checkpoint, replay
        the delta log, re-register every maintainer (one batched wave).
      * re-capture — what the engine would pay without the protocol: the
        shard must still be rebuilt from the coordinator's current table
        (a killed process has NO state — this cost is not optional), then
        the index is evicted and every sketch re-admitted from scratch
        (selection + full-table capture + registration on all shards).
    """
    db = Database({"crimes": make_crimes(n_rows, seed=23)})
    qs = _recovery_queries(db)
    t = make_crimes(200, seed=77)
    batch = {a: np.asarray(t[a]) for a in t.schema}

    def setup():
        se = _subprocess_engine(db, "crimes", "district", 4,
                                min_selectivity_gain=0.5)
        created = 0
        for q in qs:
            _, info = se.run(q)
            created += info.created
            se.run(q)
        assert created >= 4  # a sketch-rich index, not one shared sketch
        se.shards[1].inject("kill")  # a real SIGKILL
        se.run(qs[0])  # degraded serve: suspect
        se.run(qs[0])  # degraded serve: dead
        se.append_rows("crimes", batch)  # logged for the dead shard
        se._catch_up_all()  # healthy shards apply the batch (both paths pay
        se.shards[1].heal()  # this); then respawn from the pool
        return se

    t_recover = float("inf")
    for _ in range(RECOVERY_CYCLES):
        se = setup()
        try:
            t0 = time.perf_counter()
            applied, down = se._catch_up_all()  # ckpt -> replay -> re-reg
            t_recover = min(t_recover, time.perf_counter() - t0)
            assert not down and se.health[1] == "healthy"
            res, info = se.run(qs[0])
            assert not info.degraded
            assert res.canonical() == execute(qs[0], se.db).canonical()
        finally:
            se.shutdown()

    t_recapture = float("inf")
    for _ in range(RECOVERY_CYCLES):
        se = setup()
        try:
            for e in list(se.engine.index.entries()):
                se.engine.index.remove(e)
                se._unregister(e.reg_id)
            t0 = time.perf_counter()
            se._rebuild_shard(1)  # mandatory either way: the state is gone
            created = 0
            for q in qs:
                _, info = se.run(q)
                created += info.created
            t_recapture = min(t_recapture, time.perf_counter() - t0)
            assert created >= 4
            res, info = se.run(qs[0])
            assert not info.degraded
            assert res.canonical() == execute(qs[0], se.db).canonical()
        finally:
            se.shutdown()
    return t_recover, t_recapture


def run(scale: str = "quick", json_path: str | None = None):
    from repro.core import shard_rpc

    shard_rpc.POOL.prewarm(4)  # overlap server spawns with dataset setup
    try:
        total, identical, failures = _run_differential(scale)
        t_sub, t_loop = _run_overhead(60_000 if scale == "quick" else 120_000)
        # Recovery needs a table where capture cost is visible against the
        # fixed cold-respawn tax (trace/compile in a fresh process).
        t_recover, t_recapture = _run_recovery(
            200_000 if scale == "quick" else 400_000)
    finally:
        shard_rpc.POOL.shutdown_all()

    overhead = t_sub / max(t_loop, 1e-9)
    recovery_speedup = t_recapture / max(t_recover, 1e-9)
    rows = [
        ("rpc_differential", total, identical, len(failures), "", ""),
        ("rpc_overhead", "", "", "", f"{t_sub*1e3:.3f}", f"{overhead:.3f}"),
        ("rpc_recovery", "", "", "", f"{t_recover*1e3:.3f}",
         f"{recovery_speedup:.2f}"),
    ]
    emit(rows, ("bench", "sequences", "identical", "diverged", "ms", "ratio"))

    if json_path:  # write before the gates: the artifact lands either way
        with open(json_path, "w") as f:
            json.dump({
                "bench": "rpc", "scale": scale,
                "differential": {
                    "sequences": total, "identical": identical,
                    "min_sequences": MIN_SEQUENCES,
                    "backend": "subprocess-vs-loopback-fused",
                    "failures": failures,
                },
                "overhead": {
                    "t_subprocess_ms": round(t_sub * 1e3, 3),
                    "t_loopback_ms": round(t_loop * 1e3, 3),
                    "ratio": round(overhead, 4),
                    "max_ratio": MAX_TRANSPORT_OVERHEAD,
                },
                "recovery": {
                    "t_recover_ms": round(t_recover * 1e3, 3),
                    "t_recapture_ms": round(t_recapture * 1e3, 3),
                    "speedup": round(recovery_speedup, 2),
                    "min_speedup": MIN_RECOVERY_SPEEDUP,
                },
            }, f, indent=2)
        print(f"# wrote {json_path}")

    if scale == "quick":
        assert total >= MIN_SEQUENCES, (
            f"only {total} replay sequences (gate: >= {MIN_SEQUENCES})")
        assert identical == total, (
            f"{len(failures)} multi-process traces diverged from the "
            f"single-process fused replay: {failures[:5]}")
        assert overhead <= MAX_TRANSPORT_OVERHEAD, (
            f"subprocess warm hit costs {overhead:.3f}x the in-process routed "
            f"warm hit ({t_sub*1e3:.3f}ms vs {t_loop*1e3:.3f}ms); gate <= "
            f"{MAX_TRANSPORT_OVERHEAD}x")
        assert recovery_speedup >= MIN_RECOVERY_SPEEDUP, (
            f"process-kill recovery ({t_recover*1e3:.2f}ms) is only "
            f"{recovery_speedup:.2f}x cheaper than cold re-capture "
            f"({t_recapture*1e3:.2f}ms); gate >= {MIN_RECOVERY_SPEEDUP}x")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", choices=["quick", "full"], default="quick")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    run(scale="quick" if args.quick else args.scale,
        json_path="BENCH_rpc.json" if args.json else None)
