"""Fig. 9: cumulative end-to-end workload runtime per strategy, starting from
an empty sketch index (sampling + estimation + capture overhead up front,
reuse pays it back).  Workloads mix repeated templates so the sketch index
gets hits, as in the paper's setup."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import bench_databases, emit
from repro.core.engine import PBDSEngine
from repro.core.workload import STARS_SPEC, TPCH_SPEC, generate_workload

STRATEGIES = ("NO-PS", "RAND-PK", "RAND-GB", "CB-OPT-GB")


def run(scale: str = "quick", n_unique: int = 8, n_repeat: int = 5):
    rows = []
    for ds, spec in (("tpch", TPCH_SPEC), ("stars", STARS_SPEC)):
        db = bench_databases(scale)[ds]
        base = generate_workload(spec, db, n_unique, seed=9)
        rng = np.random.default_rng(9)
        workload = [base[i] for i in rng.integers(0, len(base), n_unique * n_repeat)]
        for strat in STRATEGIES:
            eng = PBDSEngine(db, strategy=strat, n_ranges=100, theta=0.05, seed=9)
            cum = 0.0
            marks = []
            for i, q in enumerate(workload):
                t0 = time.perf_counter()
                eng.run(q)
                cum += time.perf_counter() - t0
                if (i + 1) % 10 == 0:
                    marks.append(round(cum, 3))
            rows.append(("fig9", ds, strat, f"{cum:.3f}",
                         eng.index.hits, eng.index.misses, " ".join(map(str, marks))))
    return emit(rows, ("bench", "dataset", "strategy", "cum_s", "idx_hits",
                       "idx_misses", "cum_marks_every10"))


if __name__ == "__main__":
    run()
