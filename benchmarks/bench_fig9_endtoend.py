"""Fig. 9: cumulative end-to-end workload runtime per strategy, starting from
an empty sketch index (sampling + estimation + capture overhead up front,
reuse pays it back).  Workloads mix repeated templates so the sketch index
gets hits, as in the paper's setup.

Besides the CSV rows this benchmark tracks the per-phase split
(t_select / t_capture / t_execute) and the mean execution time of
*reused-sketch* runs — the index-hit path whose cost the catalog +
fragment-skipping executor is designed to flatten.  ``--json`` (via
``benchmarks.run``) writes ``BENCH_fig9.json`` with those numbers and, when
``benchmarks/seed_fig9_baseline.json`` is present, the speedup over the
pre-catalog seed measurement.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import bench_databases, emit
from repro.core.engine import PBDSEngine
from repro.core.workload import STARS_SPEC, TPCH_SPEC, generate_workload

STRATEGIES = ("NO-PS", "RAND-PK", "RAND-GB", "CB-OPT-GB")

SEED_BASELINE_PATH = os.path.join(os.path.dirname(__file__), "seed_fig9_baseline.json")

# Acceptance gates, asserted at --quick (the scale CI's selection-smoke runs).
# Cost-based selection must not dominate the admission pipeline it feeds:
# cumulative CB-OPT-GB t_select stays within 2x of cumulative t_capture.  The
# denominator gets a small absolute floor so a dataset whose captures are
# near-free cannot fail the ratio on noise alone.
GATE_SELECT_VS_CAPTURE = 2.0
GATE_CAPTURE_FLOOR_S = 0.25
# Reuse-aware admission exists so CB-OPT-GB stops declining recurring broad
# templates (stars: ~all-pass HAVINGs estimate selectivity 1.0) and losing
# the index-hit race to RAND-GB.
GATE_HITS_DATASET = "stars"


def run(scale: str = "quick", n_unique: int = 8, n_repeat: int = 5, json_path: str | None = None):
    rows = []
    results = []
    for ds, spec in (("tpch", TPCH_SPEC), ("stars", STARS_SPEC)):
        db = bench_databases(scale)[ds]
        base = generate_workload(spec, db, n_unique, seed=9)
        rng = np.random.default_rng(9)
        workload = [base[i] for i in rng.integers(0, len(base), n_unique * n_repeat)]
        for strat in STRATEGIES:
            eng = PBDSEngine(db, strategy=strat, n_ranges=100, theta=0.05, seed=9)
            cum = 0.0
            marks = []
            phase = {"t_select": 0.0, "t_capture": 0.0, "t_execute": 0.0,
                     "t_probe": 0.0, "t_repair": 0.0}
            reused_exec = []
            for i, q in enumerate(workload):
                t0 = time.perf_counter()
                _, info = eng.run(q)
                cum += time.perf_counter() - t0
                phase["t_select"] += info.t_select
                phase["t_capture"] += info.t_capture
                phase["t_execute"] += info.t_execute
                phase["t_probe"] += info.t_probe
                phase["t_repair"] += info.t_repair
                if info.reused:
                    # Pure execution: probe/repair are reported separately
                    # now instead of silently inflating the reuse numbers.
                    reused_exec.append(info.t_execute)
                if (i + 1) % 10 == 0:
                    marks.append(round(cum, 3))
            reused_mean = float(np.mean(reused_exec)) if reused_exec else None
            results.append(dict(
                dataset=ds,
                strategy=strat,
                cum_s=round(cum, 4),
                t_select_s=round(phase["t_select"], 4),
                t_capture_s=round(phase["t_capture"], 4),
                t_execute_s=round(phase["t_execute"], 4),
                t_probe_s=round(phase["t_probe"], 6),
                t_repair_s=round(phase["t_repair"], 6),
                reused_exec_mean_s=round(reused_mean, 6) if reused_mean is not None else None,
                reused_exec_count=len(reused_exec),
                idx_hits=eng.index.hits,
                idx_misses=eng.index.misses,
            ))
            rows.append(("fig9", ds, strat, f"{cum:.3f}",
                         f"{phase['t_select']:.3f}", f"{phase['t_capture']:.3f}",
                         f"{phase['t_execute']:.3f}",
                         f"{phase['t_probe']:.4f}", f"{phase['t_repair']:.4f}",
                         f"{reused_mean:.5f}" if reused_mean is not None else "",
                         eng.index.hits, eng.index.misses, " ".join(map(str, marks))))
    emit(rows, ("bench", "dataset", "strategy", "cum_s", "t_select_s", "t_capture_s",
                "t_execute_s", "t_probe_s", "t_repair_s", "reused_exec_mean_s",
                "idx_hits", "idx_misses", "cum_marks_every10"))
    gates = _check_gates(results, scale)
    if json_path:
        payload = {
            "bench": "fig9",
            "scale": scale,
            "n_unique": n_unique,
            "n_repeat": n_repeat,
            "results": results,
            "gates": gates,
        }
        if os.path.exists(SEED_BASELINE_PATH):
            with open(SEED_BASELINE_PATH) as f:
                seed = json.load(f)
            payload["seed_baseline"] = seed
            seed_by_key = {
                (r["dataset"], r["strategy"]): r.get("reused_exec_mean_s")
                for r in seed.get("results", [])
            }
            seed_counts = {
                (r["dataset"], r["strategy"]): r.get("reused_exec_count", 0)
                for r in seed.get("results", [])
            }
            speedups = {}
            seed_tot = new_tot = n_tot = 0.0
            for r in results:
                k = (r["dataset"], r["strategy"])
                ref = seed_by_key.get(k)
                if ref and r["reused_exec_mean_s"]:
                    speedups[f"{r['dataset']}/{r['strategy']}"] = round(
                        ref / r["reused_exec_mean_s"], 2
                    )
                    n = seed_counts.get(k, 0)
                    seed_tot += n * ref
                    new_tot += n * r["reused_exec_mean_s"]
                    n_tot += n
            if n_tot:
                # Hit-count-weighted mean over the configs measured in the
                # seed baseline (single-hit cells are noise-dominated).
                speedups["overall_weighted"] = round(seed_tot / new_tot, 2)
            payload["reused_exec_speedup_vs_seed"] = speedups
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


def _check_gates(results, scale: str) -> dict:
    """Selection-smoke acceptance gates; hard asserts only at --quick."""
    gates = {}
    by_key = {(r["dataset"], r["strategy"]): r for r in results}
    for ds in dict.fromkeys(r["dataset"] for r in results):
        cb = by_key.get((ds, "CB-OPT-GB"))
        if cb is None:
            continue
        ratio = cb["t_select_s"] / max(cb["t_capture_s"], GATE_CAPTURE_FLOOR_S)
        gates[f"{ds}/select_vs_capture"] = round(ratio, 3)
        if scale == "quick":
            assert ratio <= GATE_SELECT_VS_CAPTURE, (
                f"fig9 gate: {ds} CB-OPT-GB t_select {cb['t_select_s']:.2f}s is "
                f"{ratio:.2f}x t_capture {cb['t_capture_s']:.2f}s "
                f"(limit {GATE_SELECT_VS_CAPTURE}x) — selection cache / stats "
                f"prefilter / single-candidate shortcut regressed")
    cb = by_key.get((GATE_HITS_DATASET, "CB-OPT-GB"))
    rnd = by_key.get((GATE_HITS_DATASET, "RAND-GB"))
    if cb is not None and rnd is not None:
        gates[f"{GATE_HITS_DATASET}/cb_opt_gb_hits"] = cb["idx_hits"]
        gates[f"{GATE_HITS_DATASET}/rand_gb_hits"] = rnd["idx_hits"]
        if scale == "quick":
            assert cb["idx_hits"] >= rnd["idx_hits"], (
                f"fig9 gate: CB-OPT-GB index hits {cb['idx_hits']} fell below "
                f"RAND-GB {rnd['idx_hits']} on {GATE_HITS_DATASET} — "
                f"reuse-aware admission regressed")
    return gates


if __name__ == "__main__":
    run()
