"""Fig. 9: cumulative end-to-end workload runtime per strategy, starting from
an empty sketch index (sampling + estimation + capture overhead up front,
reuse pays it back).  Workloads mix repeated templates so the sketch index
gets hits, as in the paper's setup.

Besides the CSV rows this benchmark tracks the per-phase split
(t_select / t_capture / t_execute) and the mean execution time of
*reused-sketch* runs — the index-hit path whose cost the catalog +
fragment-skipping executor is designed to flatten.  ``--json`` (via
``benchmarks.run``) writes ``BENCH_fig9.json`` with those numbers and, when
``benchmarks/seed_fig9_baseline.json`` is present, the speedup over the
pre-catalog seed measurement.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import bench_databases, emit
from repro.core.engine import PBDSEngine
from repro.core.workload import STARS_SPEC, TPCH_SPEC, generate_workload

STRATEGIES = ("NO-PS", "RAND-PK", "RAND-GB", "CB-OPT-GB")

SEED_BASELINE_PATH = os.path.join(os.path.dirname(__file__), "seed_fig9_baseline.json")


def run(scale: str = "quick", n_unique: int = 8, n_repeat: int = 5, json_path: str | None = None):
    rows = []
    results = []
    for ds, spec in (("tpch", TPCH_SPEC), ("stars", STARS_SPEC)):
        db = bench_databases(scale)[ds]
        base = generate_workload(spec, db, n_unique, seed=9)
        rng = np.random.default_rng(9)
        workload = [base[i] for i in rng.integers(0, len(base), n_unique * n_repeat)]
        for strat in STRATEGIES:
            eng = PBDSEngine(db, strategy=strat, n_ranges=100, theta=0.05, seed=9)
            cum = 0.0
            marks = []
            phase = {"t_select": 0.0, "t_capture": 0.0, "t_execute": 0.0,
                     "t_probe": 0.0, "t_repair": 0.0}
            reused_exec = []
            for i, q in enumerate(workload):
                t0 = time.perf_counter()
                _, info = eng.run(q)
                cum += time.perf_counter() - t0
                phase["t_select"] += info.t_select
                phase["t_capture"] += info.t_capture
                phase["t_execute"] += info.t_execute
                phase["t_probe"] += info.t_probe
                phase["t_repair"] += info.t_repair
                if info.reused:
                    # Pure execution: probe/repair are reported separately
                    # now instead of silently inflating the reuse numbers.
                    reused_exec.append(info.t_execute)
                if (i + 1) % 10 == 0:
                    marks.append(round(cum, 3))
            reused_mean = float(np.mean(reused_exec)) if reused_exec else None
            results.append(dict(
                dataset=ds,
                strategy=strat,
                cum_s=round(cum, 4),
                t_select_s=round(phase["t_select"], 4),
                t_capture_s=round(phase["t_capture"], 4),
                t_execute_s=round(phase["t_execute"], 4),
                t_probe_s=round(phase["t_probe"], 6),
                t_repair_s=round(phase["t_repair"], 6),
                reused_exec_mean_s=round(reused_mean, 6) if reused_mean is not None else None,
                reused_exec_count=len(reused_exec),
                idx_hits=eng.index.hits,
                idx_misses=eng.index.misses,
            ))
            rows.append(("fig9", ds, strat, f"{cum:.3f}",
                         f"{phase['t_select']:.3f}", f"{phase['t_capture']:.3f}",
                         f"{phase['t_execute']:.3f}",
                         f"{phase['t_probe']:.4f}", f"{phase['t_repair']:.4f}",
                         f"{reused_mean:.5f}" if reused_mean is not None else "",
                         eng.index.hits, eng.index.misses, " ".join(map(str, marks))))
    emit(rows, ("bench", "dataset", "strategy", "cum_s", "t_select_s", "t_capture_s",
                "t_execute_s", "t_probe_s", "t_repair_s", "reused_exec_mean_s",
                "idx_hits", "idx_misses", "cum_marks_every10"))
    if json_path:
        payload = {
            "bench": "fig9",
            "scale": scale,
            "n_unique": n_unique,
            "n_repeat": n_repeat,
            "results": results,
        }
        if os.path.exists(SEED_BASELINE_PATH):
            with open(SEED_BASELINE_PATH) as f:
                seed = json.load(f)
            payload["seed_baseline"] = seed
            seed_by_key = {
                (r["dataset"], r["strategy"]): r.get("reused_exec_mean_s")
                for r in seed.get("results", [])
            }
            seed_counts = {
                (r["dataset"], r["strategy"]): r.get("reused_exec_count", 0)
                for r in seed.get("results", [])
            }
            speedups = {}
            seed_tot = new_tot = n_tot = 0.0
            for r in results:
                k = (r["dataset"], r["strategy"])
                ref = seed_by_key.get(k)
                if ref and r["reused_exec_mean_s"]:
                    speedups[f"{r['dataset']}/{r['strategy']}"] = round(
                        ref / r["reused_exec_mean_s"], 2
                    )
                    n = seed_counts.get(k, 0)
                    seed_tot += n * ref
                    new_tot += n * r["reused_exec_mean_s"]
                    n_tot += n
            if n_tot:
                # Hit-count-weighted mean over the configs measured in the
                # seed baseline (single-hit cells are noise-dominated).
                speedups["overall_weighted"] = round(seed_tot / new_tot, 2)
            payload["reused_exec_speedup_vs_seed"] = speedups
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {json_path}")
    return rows


if __name__ == "__main__":
    run()
