"""Coordinator-failover gates: takeover cost and the replication tax.

Two contracts from the failover PR's acceptance criteria, enforced at quick
scale (the CI failover-smoke job runs the pytest smoke; this bench is the
sized version):

  * **takeover** — from "the coordinator is gone" to "every workload
    template serves warm again", a standby takeover (replay replicated
    metadata, re-attach the live shard processes, stamp the new epoch,
    serve — index hits stay hits) must be >= 3x cheaper than the
    alternative without replication: build a cold coordinator over the
    same shard processes (full shard builds + ships) and re-admit every
    sketch from scratch (selection + capture + registration).
  * **tax** — streaming every metadata mutation to a warm standby must
    cost <= 5% on warm fused serving.  Warm hits emit no replication
    records at all (selection state replicates at checkpoint flush points,
    not per query), so this gate pins the hot path staying replication-free.

``--json`` (via ``benchmarks.run``) writes ``BENCH_failover.json``.
"""
from __future__ import annotations

import dataclasses
import json
import time

import numpy as np

from benchmarks.common import emit
from repro.core import Aggregate, Database, Having, Query, ShardedEngine, execute
from repro.core.datasets import make_crimes
from repro.core.standby import FailoverCoordinator

MIN_TAKEOVER_SPEEDUP = 3.0
MAX_REPLICATION_TAX = 1.05
TAKEOVER_CYCLES = 2
TAX_REPEATS = 60
RPC_OP_DEADLINE_S = 0.5
N_SHARDS = 4


def _workload_queries(db):
    """Eight distinct group-by templates, each admitting its own sketch —
    the regime takeover exists for (a re-capture pays per sketch)."""
    def q_for(gb, qt=0.7):
        q = Query("crimes", gb, Aggregate("sum", "records"))
        vals = execute(q, db).values
        return dataclasses.replace(
            q, having=Having(">", float(np.quantile(vals, qt))))

    return [q_for(("district", "year")), q_for(("year",)),
            q_for(("district", "month")), q_for(("ward", "year")),
            q_for(("community",)), q_for(("beat",)),
            q_for(("month", "year")), q_for(("zipcode",))]


def _subprocess_engine(db, **kw):
    return ShardedEngine(db, "crimes", "district", n_shards=N_SHARDS,
                         n_ranges=16, theta=0.1, seed=0,
                         min_selectivity_gain=0.5, transport="subprocess",
                         op_deadline_s=RPC_OP_DEADLINE_S, **kw)


def _run_takeover(n_rows: int):
    """Coordinator loss -> index re-populated on a serving-ready cluster.

    Both paths start identically (a sketch-rich coordinator dies while its
    shard server processes stay alive and current) and both clocks stop at
    the same condition: every previously-admitted sketch is in the index
    again and the cluster serves.

      * takeover — ``inject_coord("coord_kill")``: fold the replica's
        metadata, rebuild the index by local counting under the replicated
        reg_ids, re-attach the live shards under a bumped epoch.  Every
        prior hit is still a hit (asserted outside the clock) — nothing
        was re-captured.
      * cold rebuild — what losing the metadata would cost: construct a
        fresh coordinator over the same table (full shard builds + ships
        to every server), then re-admit every template from scratch
        (selection + full-table capture + registration on all shards).
    """
    db = Database({"crimes": make_crimes(n_rows, seed=23)})
    qs = _workload_queries(db)

    def warm_coordinator():
        """Returns the warm coordinator and which templates admitted a
        sketch (the others serve as routed scans — on both paths)."""
        fc = FailoverCoordinator(_subprocess_engine(db))
        created = 0
        admitted = []
        for q in qs:
            _, info = fc.run(q)
            created += info.created
            _, info = fc.run(q)
            admitted.append(info.reused)
        assert created >= 4  # a sketch-rich index, not one shared sketch
        return fc, admitted

    t_takeover = float("inf")
    for _ in range(TAKEOVER_CYCLES):
        fc, admitted = warm_coordinator()
        try:
            # The clock stops when the promoted coordinator is serving-ready:
            # metadata folded, index populated, live shards re-attached and
            # stamped with the new epoch (the cold clock below stops at the
            # same point — index re-populated on a running cluster).
            t0 = time.perf_counter()
            fc.inject_coord("coord_kill")
            t_takeover = min(t_takeover, time.perf_counter() - t0)
            for q, was_hit in zip(qs, admitted):
                _, info = fc.run(q)
                assert info.reused == was_hit and not info.created
            res, _ = fc.run(qs[0])
            assert res.canonical() == execute(qs[0], fc.db).canonical()
        finally:
            fc.shutdown()

    t_cold = float("inf")
    for _ in range(TAKEOVER_CYCLES):
        fc, _admitted = warm_coordinator()
        try:
            t0 = time.perf_counter()
            cold = _subprocess_engine(db)
            try:
                created = 0
                for q in qs:
                    _, info = cold.run(q)
                    created += info.created
                t_cold = min(t_cold, time.perf_counter() - t0)
                assert created >= 4  # re-captured, the cost takeover skips
                res, _ = cold.run(qs[0])
                assert res.canonical() == execute(qs[0], cold.db).canonical()
            finally:
                cold.shutdown()
        finally:
            fc.shutdown()
    return t_takeover, t_cold


def _run_tax(n_rows: int):
    """Warm fused reuse latency with and without an attached standby,
    interleaved best-of-N so runner drift hits both engines equally."""
    db = Database({"crimes": make_crimes(n_rows, seed=29)})
    base = Query("crimes", ("district", "year"), Aggregate("sum", "records"))
    q = dataclasses.replace(base, having=Having(
        ">", float(np.quantile(execute(base, db).values, 0.9))))

    def fused(**kw):
        return ShardedEngine(db, "crimes", "district", n_shards=N_SHARDS,
                             n_ranges=16, theta=0.1, seed=0,
                             min_selectivity_gain=0.5, **kw)

    replicated = FailoverCoordinator(fused())
    bare = fused()
    engines = {"replicated": replicated, "bare": bare}
    try:
        for se in engines.values():
            se.run(q)
            se.run(q)  # warm the fused stack + compile caches
        best = {"replicated": float("inf"), "bare": float("inf")}
        for _ in range(TAX_REPEATS):
            for name, se in engines.items():
                t0 = time.perf_counter()
                _, info = se.run(q)
                best[name] = min(best[name], time.perf_counter() - t0)
                assert info.reused
        assert not replicated.replica_degraded
    finally:
        replicated.shutdown()
        bare.shutdown()
    return best["replicated"], best["bare"]


def run(scale: str = "quick", json_path: str | None = None):
    from repro.core import shard_rpc

    shard_rpc.POOL.prewarm(N_SHARDS)
    try:
        t_takeover, t_cold = _run_takeover(
            120_000 if scale == "quick" else 300_000)
        t_rep, t_bare = _run_tax(60_000 if scale == "quick" else 120_000)
    finally:
        shard_rpc.POOL.shutdown_all()

    speedup = t_cold / max(t_takeover, 1e-9)
    tax = t_rep / max(t_bare, 1e-9)
    rows = [
        ("failover_takeover", f"{t_takeover*1e3:.2f}", f"{t_cold*1e3:.2f}",
         f"{speedup:.2f}"),
        ("failover_tax", f"{t_rep*1e3:.3f}", f"{t_bare*1e3:.3f}",
         f"{tax:.3f}"),
    ]
    emit(rows, ("bench", "ms", "baseline_ms", "ratio"))

    if json_path:  # write before the gates: the artifact lands either way
        with open(json_path, "w") as f:
            json.dump({
                "bench": "failover", "scale": scale,
                "takeover": {
                    "t_takeover_ms": round(t_takeover * 1e3, 3),
                    "t_cold_rebuild_ms": round(t_cold * 1e3, 3),
                    "speedup": round(speedup, 2),
                    "min_speedup": MIN_TAKEOVER_SPEEDUP,
                    "shards": N_SHARDS, "backend": "subprocess",
                },
                "tax": {
                    "t_replicated_ms": round(t_rep * 1e3, 4),
                    "t_bare_ms": round(t_bare * 1e3, 4),
                    "ratio": round(tax, 4),
                    "max_ratio": MAX_REPLICATION_TAX,
                },
            }, f, indent=2)
        print(f"# wrote {json_path}")

    if scale == "quick":
        assert speedup >= MIN_TAKEOVER_SPEEDUP, (
            f"standby takeover ({t_takeover*1e3:.1f}ms) is only "
            f"{speedup:.2f}x cheaper than cold rebuild + re-capture "
            f"({t_cold*1e3:.1f}ms); gate >= {MIN_TAKEOVER_SPEEDUP}x")
        assert tax <= MAX_REPLICATION_TAX, (
            f"replication costs {tax:.3f}x on warm fused serving "
            f"({t_rep*1e3:.3f}ms vs {t_bare*1e3:.3f}ms); gate <= "
            f"{MAX_REPLICATION_TAX}x")
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", choices=["quick", "full"], default="quick")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()
    run(scale="quick" if args.quick else args.scale,
        json_path="BENCH_failover.json" if args.json else None)
