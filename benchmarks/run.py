"""Benchmark harness: one entry per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--scale quick|full] [--only NAME] [--json]

Emits CSV per benchmark.  ``--json`` additionally writes ``BENCH_fig9.json``
(per-strategy t_select/t_capture/t_execute/t_probe/t_repair + reused-exec
means and the speedup over ``benchmarks/seed_fig9_baseline.json``),
``BENCH_maintenance.json``, ``BENCH_shard.json``, ``BENCH_admission.json``
(batched vs sequential admission, >= 3x per-query miss-path floor enforced at
quick scale), ``BENCH_chaos.json`` (>= 100 chaos-differential replay
sequences, >= 3x recovery-vs-recapture, <= 5% health-tracking tax) and
``BENCH_rpc.json`` (>= 100 cross-backend replays: real subprocess shards vs
in-process fused, <= 1.3x transport tax on warm hits, >= 3x process-kill
recovery vs cold re-capture) and ``BENCH_failover.json`` (standby takeover
>= 3x cheaper than cold rebuild + re-capture, <= 5% replication tax on warm
fused serving) so
successive PRs have a perf trajectory to compare against.  The dry-run/roofline artifacts are
produced by ``repro.launch.dryrun`` + ``benchmarks.roofline`` (they need the
512-device XLA flag and hence their own process).
"""
from __future__ import annotations

import argparse
import functools
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["quick", "full"], default="quick")
    ap.add_argument("--quick", action="store_true",
                    help="alias for --scale quick (CI smoke job)")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_*.json next to the working directory")
    args = ap.parse_args()
    if args.quick:
        args.scale = "quick"

    from benchmarks import (
        bench_ablation,
        bench_admission,
        bench_chaos,
        bench_fig4_bootstrap,
        bench_fig7_strategies,
        bench_fig8_accuracy,
        bench_failover,
        bench_fig9_endtoend,
        bench_maintenance,
        bench_rpc,
        bench_shard,
        bench_table1,
    )

    benches = {
        "table1": bench_table1.run,
        "fig4": bench_fig4_bootstrap.run,
        "fig7": bench_fig7_strategies.run,
        "fig8": bench_fig8_accuracy.run,
        "fig9": functools.partial(
            bench_fig9_endtoend.run,
            json_path="BENCH_fig9.json" if args.json else None,
        ),
        "ablation": bench_ablation.run,
        "maintenance": functools.partial(
            bench_maintenance.run,
            json_path="BENCH_maintenance.json" if args.json else None,
        ),
        "shard": functools.partial(
            bench_shard.run,
            json_path="BENCH_shard.json" if args.json else None,
        ),
        "admission": functools.partial(
            bench_admission.run,
            json_path="BENCH_admission.json" if args.json else None,
        ),
        "chaos": functools.partial(
            bench_chaos.run,
            json_path="BENCH_chaos.json" if args.json else None,
        ),
        "rpc": functools.partial(
            bench_rpc.run,
            json_path="BENCH_rpc.json" if args.json else None,
        ),
        "failover": functools.partial(
            bench_failover.run,
            json_path="BENCH_failover.json" if args.json else None,
        ),
    }
    failed = []
    for name, fn in benches.items():
        if args.only and name != args.only:
            continue
        print(f"# === {name} (scale={args.scale}) ===", flush=True)
        t0 = time.time()
        try:
            fn(scale=args.scale)
            print(f"# {name}: {time.time()-t0:.1f}s", flush=True)
        except Exception:  # noqa: BLE001
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
