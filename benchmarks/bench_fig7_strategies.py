"""Fig. 7: per-strategy comparison over the Q-AGH workload on all datasets:
(a) average query runtime with the chosen sketch, (b) average relative sketch
size, (c) expected size of random strategies (uniform over their pool).
Paper's claims to reproduce: CB-OPT ~ OPT; RAND-GB best among randoms;
CB-OPT-GB ~ CB-OPT-REL ~ OPT at lower selection overhead."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import bench_databases, emit, timeit
from repro.aqp.sampling import SampleCache
from repro.core import (
    capture_sketch, equi_depth_ranges, execute, execute_with_sketch,
    select_attribute,
)
from repro.core.sketch import actual_size
from repro.core.strategies import candidate_pool
from repro.core.workload import CRIMES_SPEC, PARKING_SPEC, STARS_SPEC, TPCH_SPEC, generate_workload

STRATEGIES = ("RAND-ALL", "RAND-REL-ALL", "RAND-GB", "RAND-PK", "RAND-AGG",
              "CB-OPT", "CB-OPT-REL", "CB-OPT-GB", "OPT")
SPECS = {"crimes": CRIMES_SPEC, "tpch": TPCH_SPEC, "parking": PARKING_SPEC,
         "stars": STARS_SPEC}


def run(scale: str = "quick", n_queries: int = 6, n_ranges: int = 100):
    dbs = bench_databases(scale)
    rows = []
    key = jax.random.PRNGKey(7)
    for ds, spec in SPECS.items():
        db = dbs[ds]
        queries = generate_workload(spec, db, n_queries, seed=7)
        ranges_cache = {}

        def ranges_for(table, a):
            if (table, a) not in ranges_cache:
                ranges_cache[(table, a)] = equi_depth_ranges(db[table], a, n_ranges)
            return ranges_cache[(table, a)]

        for strat in STRATEGIES:
            cache = SampleCache()
            rel_sizes, runtimes, t_select, expected = [], [], [], []
            for i, q in enumerate(queries):
                kq = jax.random.fold_in(key, i)
                t0 = time.perf_counter()
                sel = select_attribute(
                    strat, kq, q, db, n_ranges, cache, theta=0.05,
                    ranges_for=lambda a, q=q: ranges_for(q.table, a),
                )
                t_select.append(time.perf_counter() - t0)
                if sel.attr is None:
                    continue
                sk = capture_sketch(q, db, ranges_for(q.table, sel.attr))
                rel_sizes.append(sk.selectivity)
                t, _ = timeit(lambda sk=sk: execute_with_sketch(q, db, sk), repeats=1)
                runtimes.append(t)
                # expected size of the strategy's pool (Sec. 11.3.2);
                # cap the exact-capture work for very wide pools.
                pool = sel.candidates[:4]
                if pool:
                    expected.append(
                        np.mean([
                            actual_size(q, db, ranges_for(q.table, a)) / db[q.table].num_rows
                            for a in pool
                        ])
                    )
            if rel_sizes:
                rows.append((
                    "fig7", ds, strat,
                    f"{np.mean(rel_sizes):.4f}",
                    f"{np.mean(expected):.4f}" if expected else "-",
                    f"{np.mean(runtimes)*1e3:.1f}",
                    f"{np.mean(t_select)*1e3:.1f}",
                ))
    return emit(rows, ("bench", "dataset", "strategy", "rel_sketch_size",
                       "expected_size", "query_ms", "select_ms"))


if __name__ == "__main__":
    run()
