"""§Roofline: derive the three roofline terms per (arch x shape x mesh) cell
from the dry-run artifacts in dryrun_results.json.

  compute term    = HLO_FLOPs / (chips x 197e12 FLOP/s bf16)
  memory term     = HLO_bytes / (chips x 819e9 B/s HBM)
  collective term = collective_bytes / (chips x 50e9 B/s ICI link)

The dry-run records *per-device* numbers (post-SPMD HLO with while-loop trip
multipliers), so terms divide by per-chip peaks directly.  The memory term
uses the per-device HBM traffic proxy: argument bytes (weights/opt state read
+ written once) + 2x activation temp bytes per step.

MODEL_FLOPS = 6*N*D for training (N = params, D = tokens/step),
              2*N_active*D for inference (+ attention KV terms for decode).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict, Optional

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link

HERE = os.path.dirname(__file__)
RESULTS = os.path.join(HERE, "dryrun_results.json")


def model_flops(res: Dict, arch: str, shape: str) -> float:
    from repro.configs import get_config
    from repro.models.config import SHAPES

    cfg = get_config(arch)
    sh = SHAPES[shape]
    n_active = res.get("n_params_active") or cfg.param_count(active_only=True)
    n_total = res.get("n_params") or cfg.param_count()
    # enc-dec splits the sequence budget: each half of the params only sees
    # half the positions, so the effective token count is seq/2.
    seq = sh.seq_len // 2 if cfg.is_encdec else sh.seq_len
    if sh.kind == "train":
        tokens = seq * sh.global_batch
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = seq * sh.global_batch
        return 2.0 * n_active * tokens
    # decode: one token per sequence + attention against the KV cache
    tokens = sh.global_batch
    attn_kinds = [m for m, _ in cfg.all_blocks if m in ("attn", "swa")]
    kv_flops = 0.0
    for m in attn_kinds:
        ctx = min(sh.seq_len, cfg.sliding_window) if m == "swa" and cfg.sliding_window else sh.seq_len
        kv_flops += 4.0 * cfg.n_heads * cfg.hd * ctx * tokens
    return 2.0 * n_active * tokens + kv_flops


def roofline_row(key: str, res: Dict) -> Optional[Dict]:
    if res.get("status") != "ok":
        return None
    arch, shape, mesh = res["arch"], res["shape"], res["mesh"]
    f_dev = res["flops_per_device"]
    c_dev = res["collective_bytes_per_device"]
    mem = res["memory"]
    # HBM traffic proxy: weights+opt read & written + activations twice.
    hbm_dev = mem["argument_bytes"] * 2 + mem["temp_bytes"] * 2

    t_compute = f_dev / PEAK_FLOPS
    t_memory = hbm_dev / HBM_BW
    t_collective = c_dev / ICI_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_collective),
        key=lambda kv: kv[1],
    )[0]
    mf = model_flops(res, arch, shape)
    f_total = f_dev * res["n_devices"]
    useful = mf / f_total if f_total else 0.0
    # Roofline fraction: useful model flops per second achievable given the
    # *bound* (the dominant term), vs the all-chips peak.
    step_time = max(t_compute, t_memory, t_collective)
    mfu = mf / (step_time * res["n_devices"] * PEAK_FLOPS) if step_time > 0 else 0.0
    return {
        "arch": arch, "shape": shape, "mesh": mesh, "tag": res.get("tag", "baseline"),
        "t_compute_s": t_compute, "t_memory_s": t_memory, "t_collective_s": t_collective,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_total": f_total,
        "useful_ratio": useful,
        "roofline_fraction": mfu,
        "bytes_per_device_gib": res.get("bytes_per_device", 0) / 2**30,
        "fits_16g": res.get("bytes_per_device", 0) < 16 * 2**30,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default=RESULTS)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--markdown", action="store_true")
    args = ap.parse_args()
    with open(args.results) as f:
        allres = json.load(f)
    rows = []
    for key, res in sorted(allres.items()):
        if args.mesh and res.get("mesh") != args.mesh:
            continue
        if res.get("status") == "skipped":
            rows.append({"arch": res["arch"], "shape": res["shape"], "mesh": res["mesh"],
                         "tag": res.get("tag", ""), "skipped": res["reason"]})
            continue
        r = roofline_row(key, res)
        if r:
            rows.append(r)
    if args.markdown:
        print("| arch | shape | mesh | tag | compute s | memory s | collective s | dominant | useful | roofline frac | GiB/dev | fits |")
        print("|---|---|---|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if "skipped" in r:
                print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['tag']} | — | — | — | skipped: {r['skipped'][:40]} | | | | |")
            else:
                print(f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['tag']} | "
                      f"{r['t_compute_s']:.4f} | {r['t_memory_s']:.4f} | {r['t_collective_s']:.4f} | "
                      f"**{r['dominant']}** | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.3f} | "
                      f"{r['bytes_per_device_gib']:.2f} | {'Y' if r['fits_16g'] else 'N'} |")
    else:
        hdr = ("arch", "shape", "mesh", "tag", "t_compute_s", "t_memory_s",
               "t_collective_s", "dominant", "useful_ratio", "roofline_fraction",
               "bytes_per_device_gib")
        print(",".join(hdr))
        for r in rows:
            if "skipped" in r:
                print(f"{r['arch']},{r['shape']},{r['mesh']},{r['tag']},skipped:{r['skipped']}")
            else:
                print(",".join(str(round(r[h], 6)) if isinstance(r[h], float) else str(r[h]) for h in hdr))


if __name__ == "__main__":
    main()
