"""Fig. 8a: relative sketch-size error at 5% / 10% sample rates, and
Fig. 8b: top-k ranking accuracy (does the cost model's top-k contain the
true optimal attribute?) over CRIME / TPC-H / PARKING."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import bench_databases, emit
from repro.aqp.sampling import SampleCache
from repro.core import capture_sketch, equi_depth_ranges, select_attribute
from repro.core.workload import CRIMES_SPEC, PARKING_SPEC, TPCH_SPEC, generate_workload

SPECS = {"crimes": CRIMES_SPEC, "tpch": TPCH_SPEC, "parking": PARKING_SPEC}


def run(scale: str = "quick", n_queries: int = 10, n_ranges: int = 100):
    dbs = bench_databases(scale)
    rows = []
    key = jax.random.PRNGKey(8)
    for ds, spec in SPECS.items():
        db = dbs[ds]
        queries = generate_workload(spec, db, n_queries, seed=8)
        # ---- Fig 8a: RSE of the chosen candidate at theta in {5%, 10%} ----
        for theta in (0.05, 0.10):
            errs = []
            for i, q in enumerate(queries):
                kq = jax.random.fold_in(key, i)
                sel = select_attribute(
                    "CB-OPT-GB", kq, q, db, n_ranges, SampleCache(), theta=theta
                )
                if sel.attr is None:
                    continue
                est = sel.estimates[sel.attr]
                actual = capture_sketch(
                    q, db, equi_depth_ranges(db[q.table], sel.attr, n_ranges)
                ).size_rows
                if actual > 0:
                    errs.append(abs(est.est_rows - actual) / actual)
            rows.append(("fig8a", ds, theta, f"{np.mean(errs):.4f}", f"{np.median(errs):.4f}"))
        # ---- Fig 8b: top-k accuracy vs OPT over GB candidates -------------
        for topk in (1, 2, 3):
            hits, tot = 0, 0
            for i, q in enumerate(queries):
                kq = jax.random.fold_in(key, 1000 + i)
                opt = select_attribute("OPT", kq, q, db, n_ranges, topk=1)
                cb = select_attribute(
                    "CB-OPT-GB", kq, q, db, n_ranges, SampleCache(), theta=0.05, topk=topk
                )
                if opt.attr is None or cb.attr is None:
                    continue
                # OPT over the same (group-by) candidate pool for a fair rank test
                from repro.core.strategies import candidate_pool
                from repro.core.sketch import actual_size

                pool = candidate_pool("CB-OPT-GB", q, db, n_ranges)
                if len(pool) < 2:
                    continue
                sizes = {
                    a: actual_size(q, db, equi_depth_ranges(db[q.table], a, n_ranges))
                    for a in pool
                }
                best = min(sizes, key=sizes.get)
                tot += 1
                hits += int(best in cb.topk[:topk])
            acc = hits / tot if tot else float("nan")
            rows.append(("fig8b", ds, f"top{topk}", f"{acc:.3f}", tot))
    return emit(rows, ("bench", "dataset", "param", "value", "extra"))


if __name__ == "__main__":
    run()
