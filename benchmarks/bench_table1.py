"""Table 1: runtime of a high-crime query without sketches vs with sketches
built on different attributes (the paper: 10.1s NoPS -> 2.0s optimal attr,
~5x; a poor attribute still ~2x).  We reproduce the *relative* ordering on
the synthetic crimes dataset with the vectorized engine."""
from __future__ import annotations

import numpy as np

from benchmarks.common import bench_databases, emit, timeit
from repro.core import (
    Aggregate, Having, Query, capture_sketch, equi_depth_ranges, execute,
    execute_with_sketch,
)


def run(scale: str = "quick", n_ranges: int = 200):
    db = bench_databases(scale)["crimes"]
    q = Query(
        table="crimes",
        groupby=("district", "month", "year"),
        agg=Aggregate("sum", "records"),
        having=Having(">", float(np.quantile(
            np.asarray(execute(Query("crimes", ("district", "month", "year"),
                                     Aggregate("sum", "records")), db).values), 0.995))),
    )
    rows = []
    t_nops, base = timeit(lambda: execute(q, db))
    rows.append(("table1", "NO-PS", "-", f"{t_nops*1e3:.1f}", 1.0))
    for attr in ("district", "zipcode", "records", "beat"):
        ranges = equi_depth_ranges(db["crimes"], attr, n_ranges)
        sk = capture_sketch(q, db, ranges)
        t, res = timeit(lambda sk=sk: execute_with_sketch(q, db, sk))
        assert res.canonical() == base.canonical(), f"unsafe sketch on {attr}"
        rows.append(("table1", attr, f"{sk.selectivity:.3f}", f"{t*1e3:.1f}",
                     round(t_nops / t, 2)))
    return emit(rows, ("bench", "strategy", "selectivity", "ms", "speedup"))


if __name__ == "__main__":
    run()
